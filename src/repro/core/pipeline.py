"""Algorithm 2 — pipeline parallelization within an execution tree.

A *pipeline consumer task* carries ONE shared cache (one horizontal split)
through the tree's activities in sequence.  Each activity has a `busy` flag
guarded by a Condition: a consumer `wait()`s while the activity is processing
another split and is woken by `notify_all()` when it frees up — exactly the
paper's Algorithm 2 lines 6-11.

Admission is bounded to m' in-flight shared caches (the paper's fix-sized
BlockingQueue, lines 14-21).  Consumers run as tasks on the run's
``SharedWorkerPool`` (see executor.py) instead of a thread per split: the
pool is shared with tree-coordination tasks and §4.3 row-range work, and
every blocking wait (admission, busy/order wait, row-range join, cross-tree
channel put) is a managed-blocking region so a size-bounded pool cannot
deadlock.  ``BlockingQueue``/``HouseKeepingThread`` below keep the paper's
literal thread-queue formulation for reference and tests.

Inside-component parallelization (§4.3) hooks in here too: activities with a
configured thread count split their cache into row ranges, process the ranges
on the shared pool and merge with the row-order synchronizer.
"""
from __future__ import annotations

import queue
import threading
import time
from contextlib import nullcontext
from typing import Callable, Dict, List, Optional

from ..obs import trace as obs_trace
from . import config, faults
from .component import Component
from .executor import AdmissionGate, RunAbort, SharedWorkerPool, TaskFuture
from .graph import Dataflow
from .partitioner import ExecutionTree
from .shared_cache import SharedCache, record_copy

# deliver_fn(dst_component_name, cache, split_index, src_tree_id)
DeliverFn = Callable[[str, SharedCache, int, int], None]


class BlockingQueue:
    """Fix-sized queue of live consumer threads (paper line 14).  Kept as the
    paper's literal formulation; the engine path now bounds admission with
    ``executor.AdmissionGate`` on the shared pool instead."""

    def __init__(self, capacity: int):
        self.q: "queue.Queue" = queue.Queue(maxsize=max(1, capacity))

    def add(self, th: threading.Thread) -> None:
        self.q.put(th)      # blocks while the queue is full

    def reap(self) -> int:
        """Remove finished threads; returns the number reaped."""
        reaped = 0
        alive = []
        try:
            while True:
                th = self.q.get_nowait()
                if th.is_alive():
                    alive.append(th)
                else:
                    reaped += 1
        except queue.Empty:
            pass
        for th in alive:
            self.q.put(th)
        return reaped


class HouseKeepingThread(threading.Thread):
    """Cleans finished consumer threads out of the blocking queue so new
    consumers can be admitted (paper line 15)."""

    def __init__(self, bq: BlockingQueue, stop_evt: threading.Event,
                 interval: float = 0.001):
        super().__init__(daemon=True, name="housekeeper")
        self.bq = bq
        self.stop_evt = stop_evt
        self.interval = interval

    def run(self) -> None:
        while not self.stop_evt.is_set():
            self.bq.reap()
            time.sleep(self.interval)
        self.bq.reap()


class ActivityRunner:
    """Wraps one component as a pipeline activity with the busy/wait/notify
    protocol plus optional §4.3 multithreading."""

    def __init__(self, comp: Component, mt_threads: int = 1,
                 pool: Optional[SharedWorkerPool] = None,
                 abort: Optional[RunAbort] = None):
        self.comp = comp
        self.mt_threads = mt_threads
        self.pool = pool
        self.abort = abort

    def _ready(self, cache: SharedCache) -> bool:
        comp = self.comp
        return not comp.busy and (not comp.order_sensitive
                                  or comp.next_split == cache.split_index)

    def _acquire(self, cache: SharedCache) -> None:
        comp = self.comp
        with comp.cond:                         # fast path, no managed block
            if self._ready(cache):
                comp.busy = True                # paper line 8
                return
        ctx = self.pool.blocking() if self.pool is not None else nullcontext()
        t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
        with ctx:
            with comp.cond:
                while not self._ready(cache):
                    if self.abort is not None and self.abort.aborted:
                        self.abort.check()
                    comp.cond.wait(0.2)         # paper line 7
                comp.busy = True                # paper line 8
        if t0:
            obs_trace.on_wait("activity.busy", t0, time.perf_counter(),
                              component=comp.name, split=cache.split_index)

    def process(self, cache: SharedCache, shared: bool) -> List[SharedCache]:
        comp = self.comp
        self._acquire(cache)
        try:
            mt = (self.mt_threads > 1 and comp.supports_multithreading
                  and self.pool is not None and cache.n > self.mt_threads)
            if comp.replay_safe and faults.active():
                out = self._process_replayed(cache, shared, mt)
            elif mt:
                out = self._process_multithreaded(cache)
            else:
                out = comp.process(cache, shared=shared)    # paper line 9
        finally:
            with comp.cond:
                comp.busy = False               # paper line 10
                comp.next_split += 1
                comp.cond.notify_all()          # paper line 11
        return out

    def _process_replayed(self, cache: SharedCache, shared: bool,
                          mt: bool) -> List[SharedCache]:
        """Chunk-granular replay: transient dispatch failures rewind the
        cache to its pre-dispatch snapshot and retry in place.  Must run
        INSIDE the acquire window — the finally above advances
        ``next_split`` even on failure, so a retry at any outer level would
        deadlock order-sensitive successors.  Only entered when a fault plan
        is installed (``faults.active()``), so no-fault runs never pay for
        the snapshot."""
        comp = self.comp
        snap = faults.snapshot_cache(cache)
        retries = config.retry_max()
        delay = config.retry_backoff()
        attempt = 0
        while True:
            try:
                if mt:
                    return self._process_multithreaded(cache)
                return comp.process(cache, shared=shared)
            except BaseException as e:
                if faults.classify(e) != "transient" or attempt >= retries:
                    raise
                if self.abort is not None and self.abort.aborted:
                    raise                # the run already failed elsewhere
                faults.restore_cache(cache, snap)
                faults.record_retry(f"chunk.{comp.name}", attempt, delay)
                time.sleep(delay)
                delay = min(delay * 2.0, faults.RETRY_BACKOFF_CAP_S)
                attempt += 1

    # -------------------------------------------------- §4.3 multithreading
    def _process_multithreaded(self, cache: SharedCache) -> List[SharedCache]:
        comp = self.comp
        t0 = time.perf_counter()
        faults.inject("chunk", component=comp.name, split=cache.split_index)
        ranges = cache.row_ranges(self.mt_threads)
        fn = comp.process_range
        if config.retry_max() > 0:
            # §4.3 row-range tasks are read-only over their range, so a
            # transient task failure retries in place without a snapshot
            fn = faults.with_retries(
                fn, max_retries=config.retry_max(),
                backoff=config.retry_backoff(),
                retry_on=(faults.TransientFault,) + (ConnectionError,
                                                     TimeoutError, OSError))
        futures = [self.pool.submit(fn, cache, r) for r in ranges]
        parts = [f.result() for f in futures]       # row-order synchronizer:
        out = comp.merge_ranges(cache, ranges, parts)   # merge in input order
        t1 = time.perf_counter()
        comp.busy_time += t1 - t0
        comp.calls += 1
        comp.rows_in += cache.n
        n_out = sum(c.n for c in out)
        comp.rows_out += n_out
        if obs_trace.ACTIVE.get():
            obs_trace.on_dispatch(comp.name, t0, t1, cache.split_index,
                                  cache.n, n_out, mt=len(ranges))
        return out


class TreePipeline:
    """Executes one execution tree over a stream of input splits."""

    def __init__(self, flow: Dataflow, tree: ExecutionTree,
                 tree_of: Dict[str, int],
                 deliver: DeliverFn,
                 mt_config: Optional[Dict[str, int]] = None,
                 pool: Optional[SharedWorkerPool] = None,
                 shared: bool = True,
                 abort: Optional[RunAbort] = None):
        self.flow = flow
        self.tree = tree
        self.tree_of = tree_of
        self.deliver = deliver
        self.mt_config = mt_config or {}
        self.pool = pool
        self.shared = shared
        self.abort = abort
        self.runners: Dict[str, ActivityRunner] = {
            n: ActivityRunner(flow.component(n), self.mt_config.get(n, 1),
                              pool, abort)
            for n in tree.members
        }
        self.errors: List[BaseException] = []

    # ------------------------------------------------------------- routing
    def _route(self, node: str, outs: List[SharedCache], split_index: int) -> None:
        succs = self.flow.succ(node)
        if not succs:
            return
        per_port = len(outs) == len(succs) and len(outs) > 1
        if per_port:
            for i, u in enumerate(succs):
                out = outs[i]
                out.split_index = split_index
                if self.tree_of.get(u) == self.tree.tree_id:
                    self._walk(u, out)
                else:
                    # tree -> tree transition: COPY edge (paper §4.1); the
                    # deliver fn may block on a bounded channel (backpressure)
                    copied = out.copy()
                    record_copy(out)
                    copied.split_index = split_index
                    self.deliver(u, copied, split_index, self.tree.tree_id)
            return
        out = outs[0]
        out.split_index = split_index
        # ONE intra-tree successor consumes the shared cache in place; every
        # other successor's copy is snapshotted BEFORE any in-place walk can
        # mutate it (a compacting Filter on the first branch must not drop
        # rows from its siblings' input)
        intra = [u for u in succs if self.tree_of.get(u) == self.tree.tree_id]
        in_place = intra[0] if intra else None
        handoff: List[SharedCache] = []
        original_used = False
        for u in succs:
            if u == in_place and not original_used:
                original_used = True
                handoff.append(out)
            else:
                branch = out.copy()       # unavoidable copy on fan-out
                record_copy(out)
                branch.split_index = split_index
                handoff.append(branch)
        for u, cache in zip(succs, handoff):
            if self.tree_of.get(u) == self.tree.tree_id:
                self._walk(u, cache)
                if cache is not out:
                    cache.recycle()
            else:
                self.deliver(u, cache, split_index, self.tree.tree_id)

    def _walk(self, node: str, cache: SharedCache) -> None:
        outs = self.runners[node].process(cache, shared=self.shared)
        self._route(node, outs, cache.split_index)

    def consume_at(self, node: str, cache: SharedCache) -> None:
        """Process one delivered cache starting at an arbitrary tree member
        (cross-tree deliveries that target a non-root member, e.g. a shared
        sink)."""
        self._walk(node, cache)

    def _consume(self, cache: SharedCache, process_root: bool) -> None:
        try:
            if process_root:
                self._walk(self.tree.root, cache)
            else:
                self._route(self.tree.root, [cache], cache.split_index)
            # the split has fully flowed through the tree (sinks snapshot,
            # cross-tree successors got copies): return its arena buffers
            cache.recycle()
        except BaseException as e:
            self.errors.append(e)
            # failure path: the split's arena buffers still go back exactly
            # once (recycle is idempotent — the owned-root swap hands them
            # over on the first call only), so an aborted run leaks nothing
            # and REPRO_CACHE_GUARD=1 sees no double release
            cache.recycle()
            if self.abort is not None:
                self.abort.trip(e)

    def _consume_task(self, cache: SharedCache, process_root: bool,
                      gate: AdmissionGate) -> None:
        try:
            if self.abort is not None and self.abort.aborted:
                return
            self._consume(cache, process_root)
        finally:
            gate.release()

    # ------------------------------------------------------------ execution
    def run(self, splits, m_prime: int, process_root: bool = False) -> None:
        """Pipeline-parallel: one consumer task per split on the shared pool,
        admission bounded to m' in flight (paper lines 13-21)."""
        if self.pool is None:
            # no pool (direct library use): degenerate to sequential
            return self.run_sequential(splits, process_root)
        gate = AdmissionGate(m_prime, self.abort)
        futures: List[TaskFuture] = []
        try:
            for sc in splits:                                 # line 16
                gate.acquire(self.pool)   # line 20: blocks at m' in flight
                futures.append(self.pool.submit(
                    self._consume_task, sc, process_root, gate))  # line 21
        finally:
            for f in futures:
                f.wait()
        if self.errors:
            raise self.errors[0]

    def run_sequential(self, splits, process_root: bool = False) -> None:
        """Non-pipeline fashion: each split flows through all activities
        before the next is admitted (the m'=1 degenerate case)."""
        for sc in splits:
            if self.abort is not None and self.abort.aborted:
                break
            self._consume(sc, process_root)
        if self.errors:
            raise self.errors[0]
