"""Shared cache — the paper's §3 caching scheme.

A cache is a columnar row buffer (dict of equal-length arrays plus a
valid-row count).  The *shared caching scheme* means one cache object is
reused in place by every row-synchronized component of an execution tree:
components add/drop/overwrite columns and compact rows inside the same
object, so no output-cache -> input-cache copy ever happens.

The *ordinary* scheme (`copy()`) physically duplicates every column, which is
what the paper's baseline (Figure 3, "Copy") does on every edge.

Columns are host numpy arrays by default, but a cache may also hold
**device-resident columns** (jax.Array) produced by an accelerated operator
backend (`core/backend/`).  Device arrays are immutable, so the in-place row
mutators (``compact`` / ``take``) replace those column objects functionally
instead of writing into the buffer head; host columns keep the historical
in-place behaviour.  Every device->host crossing made here is recorded in
``CacheStats`` — the copy-cost analogue of §3 for the device tier.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np

# a column is np.ndarray (host) or a device array (e.g. jax.Array)
Columns = Dict[str, np.ndarray]


def is_host_column(v) -> bool:
    """True for mutable host (numpy) columns; device columns are anything
    else array-like (immutable, updated functionally)."""
    return isinstance(v, np.ndarray)


def _to_host(v) -> np.ndarray:
    """Materialize on host, recording the d2h transfer for device arrays."""
    if is_host_column(v):
        return v
    out = np.asarray(v)
    GLOBAL_CACHE_STATS.record_transfer("d2h", out.nbytes)
    return out


class SharedCache:
    """Columnar row buffer that can be mutated in place.

    ``split_index`` tracks which horizontal split of the execution-tree input
    this cache carries (used by the row-order synchronizer to restore global
    row order at tree leaves).
    """

    __slots__ = ("columns", "n", "split_index", "copies", "lock", "version",
                 "__weakref__")

    def __init__(self, columns: Optional[Columns] = None, n: Optional[int] = None,
                 split_index: int = 0):
        self.columns: Columns = dict(columns) if columns else {}
        if n is None:
            n = len(next(iter(self.columns.values()))) if self.columns else 0
        self.n = int(n)
        self.split_index = split_index
        self.copies = 0          # instrumentation: number of physical copies taken
        #: bumped on every mutation — device backends key cached device views
        #: of this cache on it, so a stale view is never reused
        self.version = 0
        self.lock = threading.Lock()
        self._check()

    # ------------------------------------------------------------------ util
    def _check(self) -> None:
        for k, v in self.columns.items():
            if len(v) < self.n:
                raise ValueError(f"column {k!r} shorter ({len(v)}) than n={self.n}")

    @property
    def names(self) -> List[str]:
        return list(self.columns.keys())

    def nbytes(self) -> int:
        return sum(v[: self.n].nbytes for v in self.columns.values())

    def col(self, name: str):
        """Valid slice of a column (view, no copy)."""
        return self.columns[name][: self.n]

    def to_dict(self) -> Columns:
        """Materialized host dict of valid rows (copies — for sinks/tests)."""
        return {k: np.array(_to_host(v[: self.n]))
                for k, v in self.columns.items()}

    # --------------------------------------------------------- ordinary path
    def copy(self) -> "SharedCache":
        """Physical copy — the operation the shared caching scheme removes.
        Device columns are immutable, so sharing the same array IS a safe
        copy (copy-on-write); only host buffers are duplicated."""
        out = SharedCache(
            {k: (np.array(v[: self.n]) if is_host_column(v) else v[: self.n])
             for k, v in self.columns.items()},
            self.n, self.split_index)
        self.copies += 1
        return out

    # ------------------------------------------------------- in-place mutators
    def add_column(self, name: str, values) -> None:
        if len(values) < self.n:
            raise ValueError(f"add_column {name!r}: {len(values)} < n={self.n}")
        self.columns[name] = values
        self.version += 1

    def drop_columns(self, names) -> None:
        for name in names:
            self.columns.pop(name, None)
        self.version += 1

    def keep_columns(self, names) -> None:
        names = set(names)
        for k in list(self.columns.keys()):
            if k not in names:
                del self.columns[k]
        self.version += 1

    def compact(self, mask) -> None:
        """Keep rows where ``mask`` is True, in place (row filter)."""
        if mask.dtype != np.bool_:
            raise TypeError("compact expects a boolean mask")
        if len(mask) < self.n:
            raise ValueError("mask shorter than valid rows")
        mask_h = _to_host(mask)[: self.n]
        k = int(mask_h.sum())
        for name, vals in self.columns.items():
            if is_host_column(vals):
                # write the surviving rows into the head of the SAME buffer
                vals[:k] = vals[: self.n][mask_h]
            else:
                # device column: immutable — replace functionally
                self.columns[name] = vals[: self.n][mask_h]
        self.n = k
        self.version += 1

    def take(self, idx) -> None:
        """Reorder/select rows by integer index, in place.

        ``idx`` must address the valid row window ``[0, n)`` (negative
        indices count from ``n``).  It may contain duplicates and be LONGER
        than ``n``; a host buffer too small for the gather is grown by
        allocating a fresh buffer explicitly (never by silently writing into
        the stale tail beyond the valid window)."""
        idx_h = _to_host(np.asarray(idx) if isinstance(idx, (list, tuple))
                         else idx)
        if idx_h.dtype == np.bool_:
            raise TypeError("take expects integer indices (use compact for "
                            "boolean masks)")
        k = len(idx_h)
        if k:
            lo, hi = int(idx_h.min()), int(idx_h.max())
            if lo < -self.n or hi >= self.n:
                raise IndexError(
                    f"take: index range [{lo}, {hi}] outside the valid row "
                    f"window [0, {self.n})")
        for name, vals in self.columns.items():
            if not is_host_column(vals):
                self.columns[name] = vals[: self.n][idx_h]
                continue
            gathered = vals[: self.n][idx_h]     # fancy index: fresh array
            if k <= self.n:
                vals[:k] = gathered
            else:
                # gather larger than the valid window: grow explicitly with a
                # fresh buffer instead of overwriting the stale tail
                self.columns[name] = gathered
        self.n = k
        self.version += 1

    def truncate(self, n: int) -> None:
        self.n = min(self.n, int(n))
        self.version += 1

    # ----------------------------------------------------------- partitioning
    def split(self, m: int) -> List["SharedCache"]:
        """Horizontally partition into ``m`` even splits (views, zero copy)."""
        m = max(1, min(m, max(self.n, 1)))
        bounds = np.linspace(0, self.n, m + 1).astype(np.int64)
        out = []
        for i in range(m):
            lo, hi = int(bounds[i]), int(bounds[i + 1])
            out.append(SharedCache({k: v[lo:hi] for k, v in self.columns.items()},
                                   hi - lo, split_index=i))
        return out

    def row_ranges(self, t: int) -> List[slice]:
        """Even row ranges for inside-component parallelization."""
        t = max(1, min(t, max(self.n, 1)))
        bounds = np.linspace(0, self.n, t + 1).astype(np.int64)
        return [slice(int(bounds[i]), int(bounds[i + 1])) for i in range(t)]

    def __len__(self) -> int:
        return self.n

    def __repr__(self) -> str:
        return f"SharedCache(n={self.n}, cols={self.names}, split={self.split_index})"


def _concat_column(parts: List):
    """Concatenate column parts, staying on device if any part lives there."""
    if all(is_host_column(p) for p in parts):
        return np.concatenate(parts)
    import jax.numpy as jnp              # deferred: only on device columns
    for p in parts:
        if is_host_column(p):
            GLOBAL_CACHE_STATS.record_transfer("h2d", p.nbytes)
    return jnp.concatenate([jnp.asarray(p) for p in parts])


def concat_caches(caches: List[SharedCache], ordered: bool = True) -> SharedCache:
    """Row-order synchronizer: merge caches back into one, restoring the
    original split order (paper §4.3 — 'maintains the row order of the output
    to be the same of the input').

    All caches must carry the same column set; a mismatch raises a
    ``ValueError`` naming the offending cache and columns instead of
    ``KeyError``-ing on the first cache's schema."""
    caches = [c for c in caches if c is not None]
    if not caches:
        return SharedCache({}, 0)
    if ordered:
        caches = sorted(caches, key=lambda c: c.split_index)
    names = caches[0].names
    expected = set(names)
    for i, c in enumerate(caches[1:], start=1):
        got = set(c.names)
        if got != expected:
            missing = sorted(expected - got)
            extra = sorted(got - expected)
            detail = []
            if missing:
                detail.append(f"missing {missing}")
            if extra:
                detail.append(f"unexpected {extra}")
            raise ValueError(
                f"concat_caches: cache #{i} (split {c.split_index}) column "
                f"set differs from cache #0 (split {caches[0].split_index}): "
                + ", ".join(detail))
    cols = {k: _concat_column([c.col(k) for c in caches]) for k in names}
    return SharedCache(cols, sum(c.n for c in caches))


class CacheStats:
    """Global instrumentation for copies / bytes moved (thread-safe).

    Besides host-side cache copies (the paper's §3 metric), tracks explicit
    host<->device transfers made by accelerated operator backends — the
    copy-cost analogue for the device tier."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.copies = 0
        self.bytes_copied = 0
        self.h2d_transfers = 0
        self.h2d_bytes = 0
        self.d2h_transfers = 0
        self.d2h_bytes = 0

    def record(self, cache: SharedCache) -> None:
        with self._lock:
            self.copies += 1
            self.bytes_copied += cache.nbytes()

    def record_transfer(self, direction: str, nbytes: int) -> None:
        with self._lock:
            if direction == "h2d":
                self.h2d_transfers += 1
                self.h2d_bytes += int(nbytes)
            elif direction == "d2h":
                self.d2h_transfers += 1
                self.d2h_bytes += int(nbytes)
            else:
                raise ValueError(f"unknown transfer direction {direction!r}")

    def reset(self) -> None:
        with self._lock:
            self.copies = 0
            self.bytes_copied = 0
            self.h2d_transfers = 0
            self.h2d_bytes = 0
            self.d2h_transfers = 0
            self.d2h_bytes = 0

    def snapshot(self):
        with self._lock:
            return {"copies": self.copies, "bytes_copied": self.bytes_copied,
                    "h2d_transfers": self.h2d_transfers,
                    "h2d_bytes": self.h2d_bytes,
                    "d2h_transfers": self.d2h_transfers,
                    "d2h_bytes": self.d2h_bytes}


GLOBAL_CACHE_STATS = CacheStats()
