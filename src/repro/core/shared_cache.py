"""Shared cache — the paper's §3 caching scheme.

A cache is a columnar row buffer (dict of equal-length arrays plus a
valid-row count).  The *shared caching scheme* means one cache object is
reused in place by every row-synchronized component of an execution tree:
components add/drop/overwrite columns and compact rows inside the same
object, so no output-cache -> input-cache copy ever happens.

The *ordinary* scheme (`copy()`) physically duplicates every column, which is
what the paper's baseline (Figure 3, "Copy") does on every edge.

Columns are host numpy arrays by default, but a cache may also hold
**device-resident columns** (jax.Array) produced by an accelerated operator
backend (`core/backend/`).  Device arrays are immutable, so the in-place row
mutators (``compact`` / ``take``) replace those column objects functionally
instead of writing into the buffer head; host columns keep the historical
in-place behaviour.  Every device->host crossing made here is recorded in
``CacheStats`` — the copy-cost analogue of §3 for the device tier.

Two cross-cutting services live here as well:

- ``CacheArena`` — a size-bucketed, thread-safe pool of recycled host column
  buffers.  ``SharedCache.copy``, ``concat_caches`` and the per-chunk source
  caches draw their buffers from the global arena and the executor returns
  them (``SharedCache.recycle``) once a split has fully flowed through its
  tree, so the steady state of a chunked run performs zero per-chunk host
  allocation.  Hit/miss/bytes-reused counters land in ``CacheStats``.
- **Scoped statistics** — ``cache_stats_scope`` opens a per-run
  ``CacheStats`` collector carried through ``contextvars`` (the shared
  worker pool propagates the context into its tasks), so concurrently
  benchmarked engines attribute copies/transfers/arena traffic to the right
  run instead of diffing the racy global counters.

Debug mode: ``REPRO_CACHE_GUARD=1`` enables the split-overlap check (see
``split``) and poisons released arena buffers with ``0xAB`` so any
use-after-recycle surfaces as loud data corruption instead of a silent
wrong answer.
"""
from __future__ import annotations

import contextvars
import sys
import threading
import time
from contextlib import contextmanager
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from . import config
from ..obs import trace as obs_trace

# a column is np.ndarray (host) or a device array (e.g. jax.Array)
Columns = Dict[str, np.ndarray]


def is_host_column(v) -> bool:
    """True for mutable host (numpy) columns; device columns are anything
    else array-like (immutable, updated functionally)."""
    return isinstance(v, np.ndarray)


def _to_host(v) -> np.ndarray:
    """Materialize on host, recording the d2h transfer for device arrays."""
    if is_host_column(v):
        return v
    t0 = time.perf_counter() if obs_trace.ACTIVE.get() else 0.0
    out = np.asarray(v)
    record_transfer("d2h", out.nbytes,
                    seconds=(time.perf_counter() - t0) if t0 else 0.0)
    return out


def cache_guard_enabled() -> bool:
    """True when ``REPRO_CACHE_GUARD=1``: split-overlap checks run and
    released arena buffers are poisoned (debug mode)."""
    return config.cache_guard_enabled()


def assert_views_disjoint(caches: List["SharedCache"]) -> None:
    """Debug-mode overlap check: the host-buffer byte ranges behind every
    column of the given caches must be pairwise disjoint.  ``split`` hands
    out *views* of the parent buffers; if two splits ever aliased the same
    bytes, an in-place mutation (a compacting Filter or a fused segment)
    would silently corrupt the sibling.  Raises ``RuntimeError`` naming the
    offending pair."""
    spans: Dict[str, List[Tuple[int, int, int]]] = {}
    for i, c in enumerate(caches):
        for name, v in c.columns.items():
            if not is_host_column(v) or v.nbytes == 0:
                continue
            ptr = v.__array_interface__["data"][0]
            spans.setdefault(name, []).append((ptr, ptr + v.nbytes, i))
    for name, sp in spans.items():
        sp.sort()
        for (a0, a1, i), (b0, b1, j) in zip(sp, sp[1:]):
            if b0 < a1:
                raise RuntimeError(
                    f"cache guard: splits #{i} and #{j} overlap on column "
                    f"{name!r} (byte ranges [{a0},{a1}) and [{b0},{b1}))")


class SharedCache:
    """Columnar row buffer that can be mutated in place.

    ``split_index`` tracks which horizontal split of the execution-tree input
    this cache carries (used by the row-order synchronizer to restore global
    row order at tree leaves).
    """

    __slots__ = ("columns", "n", "split_index", "copies", "lock", "version",
                 "_owned", "__weakref__")

    def __init__(self, columns: Optional[Columns] = None, n: Optional[int] = None,
                 split_index: int = 0):
        self.columns: Columns = dict(columns) if columns else {}
        if n is None:
            n = len(next(iter(self.columns.values()))) if self.columns else 0
        self.n = int(n)
        self.split_index = split_index
        self.copies = 0          # instrumentation: number of physical copies taken
        #: bumped on every mutation — device backends key cached device views
        #: of this cache on it, so a stale view is never reused
        self.version = 0
        #: root buffers drawn from the CacheArena that back this cache's host
        #: columns; returned to the pool by ``recycle()`` once the cache is
        #: consumed.  None for caches built over foreign/user arrays.
        self._owned: Optional[List[np.ndarray]] = None
        self.lock = threading.Lock()
        self._check()

    # ------------------------------------------------------------------ util
    def _check(self) -> None:
        for k, v in self.columns.items():
            if len(v) < self.n:
                raise ValueError(f"column {k!r} shorter ({len(v)}) than n={self.n}")

    @property
    def names(self) -> List[str]:
        return list(self.columns.keys())

    def nbytes(self) -> int:
        return sum(v[: self.n].nbytes for v in self.columns.values())

    def col(self, name: str):
        """Valid slice of a column (view, no copy)."""
        return self.columns[name][: self.n]

    def to_dict(self) -> Columns:
        """Materialized host dict of valid rows (copies — for sinks/tests)."""
        return {k: np.array(_to_host(v[: self.n]))
                for k, v in self.columns.items()}

    # --------------------------------------------------------- ordinary path
    def copy(self) -> "SharedCache":
        """Physical copy — the operation the shared caching scheme removes.
        Device columns are immutable, so sharing the same array IS a safe
        copy (copy-on-write); only host buffers are duplicated (drawn from
        the global ``CacheArena`` so the bytes are recycled, not freshly
        allocated, once the copy is consumed)."""
        cols: Columns = {}
        owned: List[np.ndarray] = []
        for k, v in self.columns.items():
            if is_host_column(v):
                arr, root = GLOBAL_ARENA.acquire_copy(v[: self.n])
                cols[k] = arr
                if root is not None:
                    owned.append(root)
            else:
                cols[k] = v[: self.n]
        out = SharedCache(cols, self.n, self.split_index)
        out._owned = owned or None
        self.copies += 1
        return out

    def recycle(self) -> None:
        """Return this cache's arena-owned host buffers to the pool.

        Call ONLY when the cache is fully consumed: its columns still view
        the returned buffers, so any later read observes whatever the next
        borrower wrote (under ``REPRO_CACHE_GUARD=1`` the bytes are poisoned
        with ``0xAB`` to make such misuse loud).  Idempotent; a no-op for
        caches that own no arena buffers (user caches, splits, snapshots)."""
        owned, self._owned = self._owned, None
        if owned:
            for root in owned:
                GLOBAL_ARENA.release(root)

    # ------------------------------------------------------- in-place mutators
    def add_column(self, name: str, values) -> None:
        if len(values) < self.n:
            raise ValueError(f"add_column {name!r}: {len(values)} < n={self.n}")
        self.columns[name] = values
        self.version += 1

    def drop_columns(self, names) -> None:
        for name in names:
            self.columns.pop(name, None)
        self.version += 1

    def keep_columns(self, names) -> None:
        names = set(names)
        for k in list(self.columns.keys()):
            if k not in names:
                del self.columns[k]
        self.version += 1

    def compact(self, mask) -> None:
        """Keep rows where ``mask`` is True, in place (row filter)."""
        if mask.dtype != np.bool_:
            raise TypeError("compact expects a boolean mask")
        if len(mask) < self.n:
            raise ValueError("mask shorter than valid rows")
        mask_h = _to_host(mask)[: self.n]
        k = int(mask_h.sum())
        for name, vals in self.columns.items():
            if is_host_column(vals):
                # write the surviving rows into the head of the SAME buffer
                vals[:k] = vals[: self.n][mask_h]
            else:
                # device column: immutable — replace functionally
                self.columns[name] = vals[: self.n][mask_h]
        self.n = k
        self.version += 1

    def take(self, idx) -> None:
        """Reorder/select rows by integer index, in place.

        ``idx`` must address the valid row window ``[0, n)`` (negative
        indices count from ``n``).  It may contain duplicates and be LONGER
        than ``n``; a host buffer too small for the gather is grown by
        allocating a fresh buffer explicitly (never by silently writing into
        the stale tail beyond the valid window)."""
        idx_h = _to_host(np.asarray(idx) if isinstance(idx, (list, tuple))
                         else idx)
        if idx_h.dtype == np.bool_:
            raise TypeError("take expects integer indices (use compact for "
                            "boolean masks)")
        k = len(idx_h)
        if k:
            lo, hi = int(idx_h.min()), int(idx_h.max())
            if lo < -self.n or hi >= self.n:
                raise IndexError(
                    f"take: index range [{lo}, {hi}] outside the valid row "
                    f"window [0, {self.n})")
        for name, vals in self.columns.items():
            if not is_host_column(vals):
                self.columns[name] = vals[: self.n][idx_h]
                continue
            gathered = vals[: self.n][idx_h]     # fancy index: fresh array
            if k <= self.n:
                vals[:k] = gathered
            else:
                # gather larger than the valid window: grow explicitly with a
                # fresh buffer instead of overwriting the stale tail
                self.columns[name] = gathered
        self.n = k
        self.version += 1

    def truncate(self, n: int) -> None:
        self.n = min(self.n, int(n))
        self.version += 1

    # ----------------------------------------------------------- partitioning
    def split(self, m: int) -> List["SharedCache"]:
        """Horizontally partition into ``m`` even splits (views, zero copy).

        ALIASING CONTRACT: each split's host columns are *views* of this
        cache's buffers over disjoint, contiguous row ranges — no bytes are
        copied.  A split may therefore be mutated in place (compact / take /
        a fused segment) only within its own range, which the in-place
        mutators guarantee by construction; the parent must outlive its
        splits and must not be recycled while any split is in flight.  Under
        ``REPRO_CACHE_GUARD=1`` the handed-out views are checked for pairwise
        byte-range overlap so a bounds-computation bug can never silently
        corrupt a sibling split."""
        m = max(1, min(m, max(self.n, 1)))
        bounds = np.linspace(0, self.n, m + 1).astype(np.int64)
        out = []
        for i in range(m):
            lo, hi = int(bounds[i]), int(bounds[i + 1])
            out.append(SharedCache({k: v[lo:hi] for k, v in self.columns.items()},
                                   hi - lo, split_index=i))
        if cache_guard_enabled():
            assert_views_disjoint(out)
        return out

    def row_ranges(self, t: int) -> List[slice]:
        """Even row ranges for inside-component parallelization."""
        t = max(1, min(t, max(self.n, 1)))
        bounds = np.linspace(0, self.n, t + 1).astype(np.int64)
        return [slice(int(bounds[i]), int(bounds[i + 1])) for i in range(t)]

    def __len__(self) -> int:
        return self.n

    def __repr__(self) -> str:
        return f"SharedCache(n={self.n}, cols={self.names}, split={self.split_index})"


def _concat_column(parts: List):
    """Concatenate column parts, staying on device if any part lives there."""
    if all(is_host_column(p) for p in parts):
        return np.concatenate(parts)
    import jax.numpy as jnp              # deferred: only on device columns
    for p in parts:
        if is_host_column(p):
            record_transfer("h2d", p.nbytes)
    # copy=True for host parts: jax zero-copies numpy onto the CPU "device",
    # which would alias arena-recycled buffers (the input caches are
    # recycled right after this merge)
    return jnp.concatenate([p if not is_host_column(p)
                            else jnp.array(p, copy=True) for p in parts])


def _concat_column_arena(parts: List, owned: List[np.ndarray]):
    """Host concat into an arena buffer when the parts agree on dtype and
    trailing shape; falls back to ``_concat_column`` otherwise."""
    if (all(is_host_column(p) for p in parts)
            and len({p.dtype for p in parts}) == 1
            and len({p.shape[1:] for p in parts}) == 1):
        total = sum(len(p) for p in parts)
        arr, root = GLOBAL_ARENA.acquire(parts[0].dtype,
                                         (total,) + parts[0].shape[1:])
        off = 0
        for p in parts:
            arr[off:off + len(p)] = p
            off += len(p)
        if root is not None:
            owned.append(root)
        return arr
    return _concat_column(parts)


def concat_caches(caches: List[SharedCache], ordered: bool = True,
                  recycle_inputs: bool = False) -> SharedCache:
    """Row-order synchronizer: merge caches back into one, restoring the
    original split order (paper §4.3 — 'maintains the row order of the output
    to be the same of the input').

    All caches must carry the same column set; a mismatch raises a
    ``ValueError`` naming the offending cache and columns instead of
    ``KeyError``-ing on the first cache's schema.

    The merged host columns are drawn from the global ``CacheArena``.  With
    ``recycle_inputs=True`` the caller hands over ownership of the parts:
    their arena buffers are recycled after the rows are copied out, so the
    inputs must not be read again (the engine's block/semi-block ``finish``
    paths, whose accumulated state is discarded afterwards).  The default
    leaves the inputs untouched — safe for callers that keep them."""
    caches = [c for c in caches if c is not None]
    if not caches:
        return SharedCache({}, 0)
    if ordered:
        caches = sorted(caches, key=lambda c: c.split_index)
    names = caches[0].names
    expected = set(names)
    for i, c in enumerate(caches[1:], start=1):
        got = set(c.names)
        if got != expected:
            missing = sorted(expected - got)
            extra = sorted(got - expected)
            detail = []
            if missing:
                detail.append(f"missing {missing}")
            if extra:
                detail.append(f"unexpected {extra}")
            raise ValueError(
                f"concat_caches: cache #{i} (split {c.split_index}) column "
                f"set differs from cache #0 (split {caches[0].split_index}): "
                + ", ".join(detail))
    owned: List[np.ndarray] = []
    cols = {k: _concat_column_arena([c.col(k) for c in caches], owned)
            for k in names}
    out = SharedCache(cols, sum(c.n for c in caches))
    out._owned = owned or None
    if recycle_inputs:
        for c in caches:
            c.recycle()
    return out


class CacheStats:
    """Instrumentation for copies / bytes moved (thread-safe).

    Besides host-side cache copies (the paper's §3 metric), tracks explicit
    host<->device transfers made by accelerated operator backends — the
    copy-cost analogue for the device tier — plus ``CacheArena`` buffer
    recycling (hits / misses / bytes served from the pool).

    One process-wide instance (``GLOBAL_CACHE_STATS``) always records; a
    per-run collector opened with ``cache_stats_scope`` records the same
    events for exact per-run attribution."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.copies = 0
        self.bytes_copied = 0
        self.h2d_transfers = 0
        self.h2d_bytes = 0
        self.d2h_transfers = 0
        self.d2h_bytes = 0
        self.arena_hits = 0
        self.arena_misses = 0
        self.arena_bytes_reused = 0
        self.dim_h2d_transfers = 0
        self.dim_h2d_bytes = 0
        self.segment_compiles = 0
        self.retries = 0
        self.degradations = 0
        self.faults_injected = 0

    def record(self, cache: SharedCache) -> None:
        with self._lock:
            self.copies += 1
            self.bytes_copied += cache.nbytes()

    def record_transfer(self, direction: str, nbytes: int) -> None:
        with self._lock:
            if direction == "h2d":
                self.h2d_transfers += 1
                self.h2d_bytes += int(nbytes)
            elif direction == "d2h":
                self.d2h_transfers += 1
                self.d2h_bytes += int(nbytes)
            else:
                raise ValueError(f"unknown transfer direction {direction!r}")

    def record_arena(self, hit: bool, nbytes: int) -> None:
        with self._lock:
            if hit:
                self.arena_hits += 1
                self.arena_bytes_reused += int(nbytes)
            else:
                self.arena_misses += 1

    def record_dim_upload(self, nbytes: int) -> None:
        """A dimension-table device upload (keys/payload/hash build).  Also
        recorded as a plain h2d transfer by the backend's ``asarray`` — this
        counter isolates the dim-table share so a resident serving session
        can assert warm ticks re-upload nothing."""
        with self._lock:
            self.dim_h2d_transfers += 1
            self.dim_h2d_bytes += int(nbytes)

    def record_segment_compile(self) -> None:
        """A fused-segment kernel compile: composing the host runner, or a
        jit trace of a new (bucket, column-layout) shape on an accelerated
        backend.  Warm serving ticks must record zero of these."""
        with self._lock:
            self.segment_compiles += 1

    def record_retry(self) -> None:
        """A transient failure retried (chunk replay, run re-execution, or
        serve-tick retry).  No-fault runs must record zero of these."""
        with self._lock:
            self.retries += 1

    def record_degradation(self) -> None:
        """A degradation ladder fell back one rung (segment/join/groupby
        route, or arena over-budget to direct allocation)."""
        with self._lock:
            self.degradations += 1

    def record_fault(self) -> None:
        """An injected fault fired (``core.faults``)."""
        with self._lock:
            self.faults_injected += 1

    def reset(self) -> None:
        with self._lock:
            self.copies = 0
            self.bytes_copied = 0
            self.h2d_transfers = 0
            self.h2d_bytes = 0
            self.d2h_transfers = 0
            self.d2h_bytes = 0
            self.arena_hits = 0
            self.arena_misses = 0
            self.arena_bytes_reused = 0
            self.dim_h2d_transfers = 0
            self.dim_h2d_bytes = 0
            self.segment_compiles = 0
            self.retries = 0
            self.degradations = 0
            self.faults_injected = 0

    def snapshot(self):
        with self._lock:
            return {"copies": self.copies, "bytes_copied": self.bytes_copied,
                    "h2d_transfers": self.h2d_transfers,
                    "h2d_bytes": self.h2d_bytes,
                    "d2h_transfers": self.d2h_transfers,
                    "d2h_bytes": self.d2h_bytes,
                    "arena_hits": self.arena_hits,
                    "arena_misses": self.arena_misses,
                    "arena_bytes_reused": self.arena_bytes_reused,
                    "dim_h2d_transfers": self.dim_h2d_transfers,
                    "dim_h2d_bytes": self.dim_h2d_bytes,
                    "segment_compiles": self.segment_compiles,
                    "retries": self.retries,
                    "degradations": self.degradations,
                    "faults_injected": self.faults_injected}


GLOBAL_CACHE_STATS = CacheStats()

# ---------------------------------------------------------------------------
#  Scoped (per-run) statistics
# ---------------------------------------------------------------------------
#: active per-run collectors; carried through contextvars so the shared
#: worker pool propagates a run's scope into its tasks (see
#: SharedWorkerPool.submit) and concurrent engines never cross-attribute
_STATS_SCOPES: "contextvars.ContextVar[tuple]" = contextvars.ContextVar(
    "repro_cache_stats_scopes", default=())


@contextmanager
def cache_stats_scope(stats: Optional[CacheStats] = None):
    """Open a per-run ``CacheStats`` collector.  Every copy / transfer /
    arena event recorded while the scope is active (including on worker-pool
    tasks submitted under it) lands in the yielded collector as well as in
    ``GLOBAL_CACHE_STATS``.  Scopes nest: a benchmark section scope and the
    engine's own run scope both see the run's events."""
    s = stats if stats is not None else CacheStats()
    token = _STATS_SCOPES.set(_STATS_SCOPES.get() + (s,))
    try:
        yield s
    finally:
        _STATS_SCOPES.reset(token)


def _all_stats():
    return (GLOBAL_CACHE_STATS,) + _STATS_SCOPES.get()


def absorb_external(snap: dict) -> None:
    """Fold a ``CacheStats.snapshot()`` measured in another process (a
    process-route shard worker) into the global collector and every active
    scope, so child-process copies/transfers stay visible to run- and
    benchmark-level attribution exactly as in-process work does."""
    for s in _all_stats():
        with s._lock:
            for k, v in snap.items():
                if v:
                    setattr(s, k, getattr(s, k) + int(v))


def record_copy(cache: SharedCache) -> None:
    """Record one physical cache copy in the global and scoped collectors
    (and, under an active trace scope, as an ``obs`` event + metric)."""
    for s in _all_stats():
        s.record(cache)
    if obs_trace.ACTIVE.get():
        obs_trace.on_copy(cache.nbytes())


def record_transfer(direction: str, nbytes: int, seconds: float = 0.0) -> None:
    """Record one host<->device transfer in the global + scoped collectors.
    ``seconds`` is the measured copy duration where the caller timed it —
    trace spans get a real width, ``CacheStats`` ignores it.  This funnel is
    the single source for both ``CacheStats`` and the ``obs`` tracer, which
    is what makes their transfer counters reconcile exactly."""
    for s in _all_stats():
        s.record_transfer(direction, nbytes)
    if obs_trace.ACTIVE.get():
        obs_trace.on_transfer(direction, nbytes, seconds)


def _record_arena(hit: bool, nbytes: int) -> None:
    for s in _all_stats():
        s.record_arena(hit, nbytes)
    if obs_trace.ACTIVE.get():
        obs_trace.on_arena(hit, nbytes)


def record_dim_upload(nbytes: int) -> None:
    """Record one dimension-table device upload (in ADDITION to the h2d
    transfer the backend's ``asarray`` records for the same bytes)."""
    for s in _all_stats():
        s.record_dim_upload(nbytes)


def record_segment_compile() -> None:
    """Record one fused-segment kernel compile / new-layout jit trace."""
    for s in _all_stats():
        s.record_segment_compile()


# ---------------------------------------------------------------------------
#  CacheArena — recycled host column buffers
# ---------------------------------------------------------------------------
#: smallest pooled bucket; requests below it still round up to this
_ARENA_MIN_BUCKET = 256


def _faults_active() -> bool:
    """True when any fault plan is installed.  Import-cycle-safe: the faults
    module imports us, so scope-installed plans are only checked when it is
    already loaded (a plan cannot exist otherwise)."""
    if config.faults_spec() is not None:
        return True
    mod = sys.modules.get(__package__ + ".faults")
    return mod is not None and bool(mod._SCOPES.get())


class CacheArena:
    """Size-bucketed, thread-safe pool of recycled host column buffers.

    ``acquire`` returns a correctly-typed array *view* over a pow2-sized
    ``uint8`` root buffer (popped from the pool on a hit, freshly allocated
    on a miss) together with that root; callers record roots on the caches
    they build (``SharedCache._owned``) and hand them back via
    ``SharedCache.recycle`` / ``release`` once the cache is consumed.  Pooled
    bytes are capped (``REPRO_ARENA_MAX_MB``, default 256) — releases beyond
    the cap simply drop the buffer to the GC.

    ``REPRO_ARENA=0`` disables pooling entirely: ``acquire`` falls back to
    plain allocation and hands back no root, so every release is a no-op.
    Under ``REPRO_CACHE_GUARD=1`` released buffers are poisoned with ``0xAB``
    and a double release raises instead of being ignored."""

    def __init__(self, max_bytes: Optional[int] = None,
                 enabled: Optional[bool] = None):
        if enabled is None:
            enabled = config.arena_enabled()
        if max_bytes is None:
            max_bytes = config.arena_max_bytes()
        self.enabled = bool(enabled)
        self.max_bytes = int(max_bytes)
        self._lock = threading.Lock()
        self._pools: Dict[int, List[np.ndarray]] = {}
        self._pooled_bytes = 0
        self._pooled_ids: set = set()

    @staticmethod
    def _bucket(nbytes: int) -> int:
        b = _ARENA_MIN_BUCKET
        while b < nbytes:
            b <<= 1
        return b

    # ------------------------------------------------------------------ API
    def acquire(self, dtype, shape) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Borrow a ``(view, root)`` pair for an array of ``dtype``/``shape``.
        ``root`` is None when pooling is disabled (nothing to give back)."""
        dtype = np.dtype(dtype)
        if not isinstance(shape, (tuple, list)):
            shape = (shape,)
        shape = tuple(int(s) for s in shape)
        if not self.enabled:
            return np.empty(shape, dtype), None
        if _faults_active():
            # injected over-budget condition: degrade to direct allocation
            # (root=None => the release path is a no-op) instead of raising
            from . import faults as _faults       # lazy: faults imports us
            try:
                _faults.inject("arena", component="acquire")
            except _faults.FaultError as e:
                _faults.record_degradation("arena", src="pooled",
                                           dst="direct", error=repr(e))
                return np.empty(shape, dtype), None
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize \
            if shape else dtype.itemsize
        bucket = self._bucket(nbytes)
        root = None
        with self._lock:
            pool = self._pools.get(bucket)
            if pool:
                root = pool.pop()
                self._pooled_bytes -= bucket
                self._pooled_ids.discard(id(root))
        if root is None:
            root = np.empty(bucket, np.uint8)
            _record_arena(False, nbytes)
        else:
            _record_arena(True, nbytes)
        return root[:nbytes].view(dtype).reshape(shape), root

    def acquire_like(self, arr) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        return self.acquire(arr.dtype, arr.shape)

    def acquire_copy(self, src: np.ndarray) -> Tuple[np.ndarray,
                                                     Optional[np.ndarray]]:
        """Borrow a buffer shaped/typed like ``src`` with its rows copied in
        — the one pattern every arena-backed cache builder uses."""
        arr, root = self.acquire(src.dtype, src.shape)
        np.copyto(arr, src)
        return arr, root

    def release(self, root: Optional[np.ndarray]) -> None:
        """Return a root buffer to the pool.  Non-arena arrays (wrong dtype /
        shape / non-owning) are ignored, so callers may pass anything they
        recorded without re-checking provenance."""
        if root is None or not self.enabled:
            return
        if not (isinstance(root, np.ndarray) and root.dtype == np.uint8
                and root.ndim == 1 and root.flags["OWNDATA"]):
            return
        bucket = root.nbytes
        if bucket < _ARENA_MIN_BUCKET or bucket & (bucket - 1):
            return                       # not one of our pow2 buckets
        guard = cache_guard_enabled()
        with self._lock:
            if id(root) in self._pooled_ids:
                if guard:
                    raise RuntimeError("CacheArena: double release of the "
                                       "same buffer")
                return
            if self._pooled_bytes + bucket > self.max_bytes:
                return                   # over budget: drop to the GC
            if guard:
                root.fill(0xAB)          # poison: use-after-recycle is loud
            self._pools.setdefault(bucket, []).append(root)
            self._pooled_bytes += bucket
            self._pooled_ids.add(id(root))
        # the trace event only AFTER the buffer is actually accepted into the
        # pool: a rejected release (double release, over budget, foreign
        # buffer) must not inflate another run's arena-release accounting
        if obs_trace.ACTIVE.get():
            obs_trace.on_arena_release(bucket)

    # -------------------------------------------------------------- observe
    @property
    def pooled_bytes(self) -> int:
        with self._lock:
            return self._pooled_bytes

    def pooled_buffers(self) -> int:
        with self._lock:
            return sum(len(p) for p in self._pools.values())

    def clear(self) -> None:
        with self._lock:
            self._pools.clear()
            self._pooled_bytes = 0
            self._pooled_ids.clear()


GLOBAL_ARENA = CacheArena()
