"""Shared cache — the paper's §3 caching scheme.

A cache is a columnar row buffer (dict of equal-length numpy arrays plus a
valid-row count).  The *shared caching scheme* means one cache object is
reused in place by every row-synchronized component of an execution tree:
components add/drop/overwrite columns and compact rows inside the same
object, so no output-cache -> input-cache copy ever happens.

The *ordinary* scheme (`copy()`) physically duplicates every column, which is
what the paper's baseline (Figure 3, "Copy") does on every edge.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np

Columns = Dict[str, np.ndarray]


class SharedCache:
    """Columnar row buffer that can be mutated in place.

    ``split_index`` tracks which horizontal split of the execution-tree input
    this cache carries (used by the row-order synchronizer to restore global
    row order at tree leaves).
    """

    __slots__ = ("columns", "n", "split_index", "copies", "lock")

    def __init__(self, columns: Optional[Columns] = None, n: Optional[int] = None,
                 split_index: int = 0):
        self.columns: Columns = dict(columns) if columns else {}
        if n is None:
            n = len(next(iter(self.columns.values()))) if self.columns else 0
        self.n = int(n)
        self.split_index = split_index
        self.copies = 0          # instrumentation: number of physical copies taken
        self.lock = threading.Lock()
        self._check()

    # ------------------------------------------------------------------ util
    def _check(self) -> None:
        for k, v in self.columns.items():
            if len(v) < self.n:
                raise ValueError(f"column {k!r} shorter ({len(v)}) than n={self.n}")

    @property
    def names(self) -> List[str]:
        return list(self.columns.keys())

    def nbytes(self) -> int:
        return sum(v[: self.n].nbytes for v in self.columns.values())

    def col(self, name: str) -> np.ndarray:
        """Valid slice of a column (view, no copy)."""
        return self.columns[name][: self.n]

    def to_dict(self) -> Columns:
        """Materialized dict of valid rows (copies — for sinks/tests)."""
        return {k: np.array(v[: self.n]) for k, v in self.columns.items()}

    # --------------------------------------------------------- ordinary path
    def copy(self) -> "SharedCache":
        """Physical copy — the operation the shared caching scheme removes."""
        out = SharedCache({k: np.array(v[: self.n]) for k, v in self.columns.items()},
                          self.n, self.split_index)
        self.copies += 1
        return out

    # ------------------------------------------------------- in-place mutators
    def add_column(self, name: str, values: np.ndarray) -> None:
        if len(values) < self.n:
            raise ValueError(f"add_column {name!r}: {len(values)} < n={self.n}")
        self.columns[name] = values

    def drop_columns(self, names) -> None:
        for name in names:
            self.columns.pop(name, None)

    def keep_columns(self, names) -> None:
        names = set(names)
        for k in list(self.columns.keys()):
            if k not in names:
                del self.columns[k]

    def compact(self, mask: np.ndarray) -> None:
        """Keep rows where ``mask`` is True, in place (row filter)."""
        if mask.dtype != np.bool_:
            raise TypeError("compact expects a boolean mask")
        if len(mask) < self.n:
            raise ValueError("mask shorter than valid rows")
        mask = mask[: self.n]
        k = int(mask.sum())
        for name, vals in self.columns.items():
            # write the surviving rows into the head of the SAME buffer
            vals[:k] = vals[: self.n][mask]
        self.n = k

    def take(self, idx: np.ndarray) -> None:
        """Reorder/select rows by integer index, in place."""
        k = len(idx)
        for name, vals in self.columns.items():
            vals[:k] = vals[: self.n][idx]
        self.n = k

    def truncate(self, n: int) -> None:
        self.n = min(self.n, int(n))

    # ----------------------------------------------------------- partitioning
    def split(self, m: int) -> List["SharedCache"]:
        """Horizontally partition into ``m`` even splits (views, zero copy)."""
        m = max(1, min(m, max(self.n, 1)))
        bounds = np.linspace(0, self.n, m + 1).astype(np.int64)
        out = []
        for i in range(m):
            lo, hi = int(bounds[i]), int(bounds[i + 1])
            out.append(SharedCache({k: v[lo:hi] for k, v in self.columns.items()},
                                   hi - lo, split_index=i))
        return out

    def row_ranges(self, t: int) -> List[slice]:
        """Even row ranges for inside-component parallelization."""
        t = max(1, min(t, max(self.n, 1)))
        bounds = np.linspace(0, self.n, t + 1).astype(np.int64)
        return [slice(int(bounds[i]), int(bounds[i + 1])) for i in range(t)]

    def __len__(self) -> int:
        return self.n

    def __repr__(self) -> str:
        return f"SharedCache(n={self.n}, cols={self.names}, split={self.split_index})"


def concat_caches(caches: List[SharedCache], ordered: bool = True) -> SharedCache:
    """Row-order synchronizer: merge caches back into one, restoring the
    original split order (paper §4.3 — 'maintains the row order of the output
    to be the same of the input')."""
    caches = [c for c in caches if c is not None]
    if not caches:
        return SharedCache({}, 0)
    if ordered:
        caches = sorted(caches, key=lambda c: c.split_index)
    names = caches[0].names
    cols = {k: np.concatenate([c.col(k) for c in caches]) for k in names}
    return SharedCache(cols, sum(c.n for c in caches))


class CacheStats:
    """Global instrumentation for copies / bytes moved (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.copies = 0
        self.bytes_copied = 0

    def record(self, cache: SharedCache) -> None:
        with self._lock:
            self.copies += 1
            self.bytes_copied += cache.nbytes()

    def reset(self) -> None:
        with self._lock:
            self.copies = 0
            self.bytes_copied = 0

    def snapshot(self):
        with self._lock:
            return {"copies": self.copies, "bytes_copied": self.bytes_copied}


GLOBAL_CACHE_STATS = CacheStats()
