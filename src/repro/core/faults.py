"""Deterministic fault injection + the runtime's retry/degradation vocabulary.

The fault-tolerance layer has three moving parts, all defined here:

1. **Taxonomy** — ``FaultError`` subclasses split failures into the three
   classes the runtime reacts to differently, and ``classify`` maps ANY
   exception (injected or real) onto the same axis:

   =============  ==========================================================
   ``transient``  worth retrying: injected ``TransientFault``, connection /
                  timeout / OS-level errors.  Chunk dispatches replay them
                  in place (``ActivityRunner``), engines re-run the
                  streaming phase, serve ticks re-tick.
   ``permanent``  not worth retrying: logic errors, bad schemas, explicit
                  ``PermanentFault``.  The run aborts promptly with the
                  original exception.
   ``poison``     the *data* is bad, not the attempt: retrying cannot help
                  and must not block the stream.  Serve sessions dead-letter
                  the micro-batch and keep ticking.
   =============  ==========================================================

2. **FaultPlan** — a seeded, declarative list of injection rules installed
   either via the ``fault_scope`` contextvar (mirrors ``cache_stats_scope``;
   scopes follow tasks across the worker pool because ``SharedWorkerPool``
   propagates contextvars) or process-wide via ``REPRO_FAULTS``.  Rules are
   matched at named injection **sites** wired through the runtime:

   =========  ==============================================================
   ``chunk``  a component dispatch (``Component.process`` /
              ``accumulate``) or a source split draw
   ``kernel`` a backend kernel launch: fused-segment runners, the jax
              join-probe and groupby routes
   ``edge``   ``ChannelGroup.put`` — cross-tree handoff (``delay=`` rules
              sleep here instead of raising)
   ``arena``  ``CacheArena.acquire`` — a fired rule simulates over-budget:
              the arena degrades to direct allocation instead of raising
   ``tick``   one ``ServeSession.tick`` micro-batch
   ``shard``  one whole shard pass of a sharded run — the coordinator
              replays the lost shard from its source snapshot
   =========  ==============================================================

   Spec grammar (``REPRO_FAULTS`` or ``FaultPlan.parse``)::

       seed=7; chunk@filter_hot:kind=transient,count=2; kernel:count=1;
       tick:p=0.25,count=10,kind=poison; edge:delay=0.005,count=3

   Rules are ``site[@component][:opt=val,...]`` separated by ``;``.  Options:
   ``kind`` (transient|permanent|poison, default transient), ``count`` (max
   fires, default 1), ``split`` (only that split index), ``after`` (skip the
   first N matching calls), ``p`` (per-call fire probability, drawn from the
   plan's seeded RNG), ``delay`` (sleep seconds instead of raising).  Counts
   are **plan-lifetime**, so a rule with ``count=1`` that already fired lets
   the retried attempt pass clean — which is exactly what makes transient
   plans survivable.

3. **Recording** — every injection, retry and degradation funnels through
   ``record_fault`` / ``record_retry`` / ``record_degradation`` into the
   scoped ``CacheStats`` counters (=> EngineRun / BENCH JSON), the obs trace
   hooks (instants + metric counters + the retry-backoff histogram), and any
   open ``fault_recorder`` scope (=> ``EngineRun.degradation_events``).

``retry_call`` is the core capped-exponential-backoff helper (the
generalization of ``train/fault.py:with_retries``): transient failures sleep
``REPRO_RETRY_BACKOFF * 2**attempt`` capped at ``RETRY_BACKOFF_CAP_S`` for up
to ``REPRO_RETRY_MAX`` retries; anything non-transient re-raises immediately.
"""
from __future__ import annotations

import random
import threading
import time
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from . import config
from . import shared_cache as _sc
from ..obs import trace as obs_trace

__all__ = [
    "FaultError", "TransientFault", "PermanentFault", "PoisonFault",
    "classify", "FaultRule", "FaultPlan", "fault_scope", "active", "inject",
    "retry_call", "with_retries", "backoff_schedule", "RETRY_BACKOFF_CAP_S",
    "Degradation", "fault_recorder", "record_fault", "record_retry",
    "record_degradation", "snapshot_cache", "restore_cache",
]

#: ceiling on a single retry backoff sleep — doubling stops here
RETRY_BACKOFF_CAP_S = 2.0

#: valid injection sites (see module docstring table)
SITES = ("chunk", "kernel", "edge", "arena", "tick", "shard")

KINDS = ("transient", "permanent", "poison")


# ---------------------------------------------------------------------------
#  Taxonomy
# ---------------------------------------------------------------------------
class FaultError(RuntimeError):
    """Base class for injected faults; ``kind`` is the classification axis."""
    kind = "permanent"


class TransientFault(FaultError):
    """Recoverable by retrying the same work (flaky I/O, lost worker)."""
    kind = "transient"


class PermanentFault(FaultError):
    """Unrecoverable — the run must abort with this exception."""
    kind = "permanent"


class PoisonFault(FaultError):
    """The input data itself is bad: retries cannot succeed, but the stream
    must not die — serving dead-letters the batch and moves on."""
    kind = "poison"


#: real-world exception types worth a retry (network / timeout / OS hiccups)
_TRANSIENT_REAL = (ConnectionError, TimeoutError, InterruptedError, OSError)


def classify(exc: BaseException) -> str:
    """Map any exception to ``"transient"`` / ``"permanent"`` / ``"poison"``.

    Injected ``FaultError``s carry their class; among real exceptions only
    connection/timeout/OS errors are considered transient — logic errors
    (ValueError, KeyError, ...) and ``ExecutionAborted`` are permanent."""
    if isinstance(exc, FaultError):
        return exc.kind
    if isinstance(exc, _TRANSIENT_REAL):
        return "transient"
    return "permanent"


# ---------------------------------------------------------------------------
#  FaultPlan
# ---------------------------------------------------------------------------
_EXC_BY_KIND = {"transient": TransientFault, "permanent": PermanentFault,
                "poison": PoisonFault}


@dataclass
class FaultRule:
    """One injection rule.  ``seen``/``fired`` are plan-lifetime runtime
    state, mutated under the owning plan's lock."""
    site: str
    component: Optional[str] = None   # None => any component
    kind: str = "transient"
    count: int = 1                    # max fires over the plan's lifetime
    split: Optional[int] = None       # only this split index
    after: int = 0                    # skip the first N matching calls
    p: float = 1.0                    # per-call fire probability
    delay_s: float = 0.0              # >0 => sleep instead of raising
    seen: int = 0
    fired: int = 0

    def matches(self, site: str, component: Optional[str],
                split: Optional[int]) -> bool:
        if site != self.site:
            return False
        if self.component is not None and component != self.component:
            return False
        if self.split is not None and split != self.split:
            return False
        return True

    def spec(self) -> Dict[str, object]:
        return {"site": self.site, "component": self.component,
                "kind": self.kind, "count": self.count, "split": self.split,
                "after": self.after, "p": self.p, "delay_s": self.delay_s,
                "seen": self.seen, "fired": self.fired}


class FaultPlan:
    """A seeded set of :class:`FaultRule`\\ s with thread-safe fire
    accounting.  Install with :func:`fault_scope` or ``REPRO_FAULTS``."""

    def __init__(self, rules: List[FaultRule], seed: int = 0,
                 spec: str = "") -> None:
        for r in rules:
            if r.site not in SITES:
                raise ValueError(
                    f"unknown fault site {r.site!r}; valid: {SITES}")
            if r.kind not in KINDS:
                raise ValueError(
                    f"unknown fault kind {r.kind!r}; valid: {KINDS}")
        self.rules = list(rules)
        self.seed = int(seed)
        self.spec = spec
        self.injected = 0
        self._rng = random.Random(self.seed)
        self._lock = threading.Lock()

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the ``REPRO_FAULTS`` grammar (module docstring)."""
        rules: List[FaultRule] = []
        seed = 0
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            if part.startswith("seed="):
                seed = int(part[5:])
                continue
            head, _, opt_str = part.partition(":")
            site, _, component = head.partition("@")
            kw: Dict[str, object] = {"site": site.strip(),
                                     "component": component.strip() or None}
            for opt in opt_str.split(","):
                opt = opt.strip()
                if not opt:
                    continue
                k, _, v = opt.partition("=")
                k, v = k.strip(), v.strip()
                if k == "kind":
                    kw["kind"] = v
                elif k in ("count", "split", "after"):
                    kw[k] = int(v)
                elif k == "p":
                    kw["p"] = float(v)
                elif k == "delay":
                    kw["delay_s"] = float(v)
                else:
                    raise ValueError(
                        f"unknown fault-rule option {k!r} in {part!r}")
            rules.append(FaultRule(**kw))
        return cls(rules, seed=seed, spec=spec)

    def reset(self) -> None:
        """Forget all fire accounting (fresh plan lifetime)."""
        with self._lock:
            self.injected = 0
            self._rng = random.Random(self.seed)
            for r in self.rules:
                r.seen = 0
                r.fired = 0

    def fire(self, site: str, component: Optional[str],
             split: Optional[int]) -> None:
        """Raise / sleep if a rule matches this call.  Called on the hot
        path only when a plan is actually installed."""
        for r in self.rules:
            if not r.matches(site, component, split):
                continue
            with self._lock:
                r.seen += 1
                if r.fired >= r.count or r.seen <= r.after:
                    continue
                if r.p < 1.0 and self._rng.random() >= r.p:
                    continue
                r.fired += 1
                self.injected += 1
            record_fault(site, r.kind, component)
            if r.delay_s > 0.0:
                time.sleep(r.delay_s)
                continue
            raise _EXC_BY_KIND[r.kind](
                f"injected {r.kind} fault at site {site!r}"
                f" (component={component!r}, split={split!r})")


# ---------------------------------------------------------------------------
#  Scope plumbing (mirrors shared_cache.cache_stats_scope)
# ---------------------------------------------------------------------------
_SCOPES: "ContextVar[Tuple[FaultPlan, ...]]" = ContextVar(
    "repro_fault_scopes", default=())

# cached parse of the REPRO_FAULTS env plan, keyed on the raw string so a
# changed env var (tests) re-parses; the plan object persists so rule fire
# counts survive across runs within one process — plan-lifetime semantics
_ENV_PLAN: Tuple[Optional[str], Optional[FaultPlan]] = (None, None)


@contextmanager
def fault_scope(plan: FaultPlan):
    """Install ``plan`` for the dynamic extent of the with-block (and any
    pool tasks submitted inside it).  Yields the plan."""
    token = _SCOPES.set(_SCOPES.get() + (plan,))
    try:
        yield plan
    finally:
        _SCOPES.reset(token)


def _env_plan(spec: str) -> FaultPlan:
    global _ENV_PLAN
    raw, plan = _ENV_PLAN
    if raw != spec or plan is None:
        plan = FaultPlan.parse(spec)
        _ENV_PLAN = (spec, plan)
    return plan


def active() -> bool:
    """Cheap check: is any fault plan installed (scope or env)?  Gates all
    snapshot/restore work so no-fault runs pay nothing."""
    return bool(_SCOPES.get()) or config.faults_spec() is not None


def inject(site: str, component: Optional[str] = None,
           split: Optional[int] = None) -> None:
    """Fire matching rules of every installed plan at this site.  No-op
    (two cheap reads) when no plan is installed."""
    plans = _SCOPES.get()
    spec = config.faults_spec()
    if not plans and spec is None:
        return
    if spec is not None:
        plans = plans + (_env_plan(spec),)
    for p in plans:
        p.fire(site, component, split)


# ---------------------------------------------------------------------------
#  Retry helpers
# ---------------------------------------------------------------------------
def backoff_schedule(retries: int, base: float,
                     cap: float = RETRY_BACKOFF_CAP_S) -> List[float]:
    """The sleep schedule ``retry_call`` uses: base * 2**i, capped."""
    return [min(base * (2.0 ** i), cap) for i in range(max(0, retries))]


def retry_call(fn: Callable, *args, where: str = "",
               max_retries: Optional[int] = None,
               backoff: Optional[float] = None,
               classify_fn: Callable[[BaseException], str] = classify,
               on_retry: Optional[Callable[[int, BaseException], None]] = None):
    """Call ``fn(*args)``, retrying transient failures with capped
    exponential backoff.  Defaults come from ``REPRO_RETRY_MAX`` /
    ``REPRO_RETRY_BACKOFF``; non-transient failures re-raise immediately."""
    retries = config.retry_max() if max_retries is None else int(max_retries)
    delay = config.retry_backoff() if backoff is None else float(backoff)
    attempt = 0
    while True:
        try:
            return fn(*args)
        except BaseException as e:
            if classify_fn(e) != "transient" or attempt >= retries:
                raise
            record_retry(where or getattr(fn, "__name__", "call"),
                         attempt, delay)
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(delay)
            delay = min(delay * 2.0, RETRY_BACKOFF_CAP_S)
            attempt += 1


def with_retries(fn: Callable, max_retries: int = 3, backoff: float = 0.1,
                 retry_on: Tuple = (RuntimeError, OSError),
                 on_retry: Optional[Callable] = None) -> Callable:
    """Wrapper form of :func:`retry_call` with an explicit ``retry_on``
    exception filter — the ``train/fault.py`` interface, now core."""
    def _classify(e: BaseException) -> str:
        return "transient" if isinstance(e, retry_on) else "permanent"

    def wrapped(*args, **kwargs):
        return retry_call(lambda: fn(*args, **kwargs),
                          where=getattr(fn, "__name__", "call"),
                          max_retries=max_retries, backoff=backoff,
                          classify_fn=_classify, on_retry=on_retry)
    wrapped.__name__ = getattr(fn, "__name__", "wrapped")
    return wrapped


# ---------------------------------------------------------------------------
#  Degradations + recording funnels
# ---------------------------------------------------------------------------
@dataclass
class Degradation:
    """One recorded fallback step: ``kind`` names the ladder (segment, join,
    groupby, arena), ``src``/``dst`` the route degraded from/to."""
    kind: str
    src: str
    dst: str
    component: Optional[str] = None
    error: str = ""

    def spec(self) -> Dict[str, object]:
        return {"kind": self.kind, "src": self.src, "dst": self.dst,
                "component": self.component, "error": self.error}


@dataclass
class FaultRecorder:
    """Collects degradation/retry detail for attachment to an EngineRun."""
    degradations: List[Degradation] = field(default_factory=list)
    retries: List[Dict[str, object]] = field(default_factory=list)


_RECORDERS: "ContextVar[Tuple[FaultRecorder, ...]]" = ContextVar(
    "repro_fault_recorders", default=())


@contextmanager
def fault_recorder():
    """Scope that captures degradation/retry events (engines open one per
    run and attach the detail to the EngineRun)."""
    rec = FaultRecorder()
    token = _RECORDERS.set(_RECORDERS.get() + (rec,))
    try:
        yield rec
    finally:
        _RECORDERS.reset(token)


def record_fault(site: str, kind: str, component: Optional[str] = None) -> None:
    """An injection fired: bump scoped counters + emit a trace instant."""
    for stats in _sc._all_stats():
        stats.record_fault()
    if obs_trace.ACTIVE.get():
        obs_trace.on_fault(site, kind, component)


def record_retry(where: str, attempt: int, delay_s: float) -> None:
    """A transient failure is about to be retried after ``delay_s``."""
    for stats in _sc._all_stats():
        stats.record_retry()
    for rec in _RECORDERS.get():
        rec.retries.append({"where": where, "attempt": attempt,
                            "delay_s": delay_s})
    if obs_trace.ACTIVE.get():
        obs_trace.on_retry(where, attempt, delay_s)


def record_degradation(kind: str, src: str, dst: str,
                       component: Optional[str] = None,
                       error: str = "") -> Degradation:
    """A ladder fell back one rung: record counters + detail."""
    d = Degradation(kind=kind, src=src, dst=dst, component=component,
                    error=error)
    for stats in _sc._all_stats():
        stats.record_degradation()
    for rec in _RECORDERS.get():
        rec.degradations.append(d)
    if obs_trace.ACTIVE.get():
        obs_trace.on_degrade(kind, src, dst, component)
    return d


# ---------------------------------------------------------------------------
#  Chunk snapshot / restore (dispatch-granular replay)
# ---------------------------------------------------------------------------
def snapshot_cache(cache) -> Dict[str, object]:
    """Capture enough of a SharedCache to replay a failed in-place dispatch.

    Host columns are copied with plain numpy (NOT arena draws — replay
    bookkeeping must not perturb arena counters); device columns are kept by
    reference (jax arrays are immutable; components replace, never mutate,
    them).  Only the live ``[:n]`` prefix is copied."""
    cols: Dict[str, object] = {}
    n = cache.n
    for name, v in cache.columns.items():
        if _sc.is_host_column(v):
            cols[name] = np.array(v[:n])
        else:
            cols[name] = v
    return {"n": n, "cols": cols}


def restore_cache(cache, snap: Dict[str, object]) -> None:
    """Rewind a cache to a snapshot before replaying the dispatch.  The
    restored columns are FRESH buffers (detached from any arena roots the
    cache owns — those are still released exactly once by the normal
    recycle path), and the version bump invalidates device views."""
    cache.columns = {name: (np.array(v) if isinstance(v, np.ndarray) else v)
                     for name, v in snap["cols"].items()}
    cache.n = snap["n"]
    cache.version += 1
