"""Execution engines.

`OrdinaryEngine` — the paper's baseline (Figure 3): every component owns a
separate output cache; on EVERY edge the rows are physically copied into the
downstream component's input cache; execution is sequential.

`OptimizedEngine` — the paper's framework: Algorithm-1 partitioning into
execution trees, shared caching inside each tree (zero copies), Algorithm-2
pipeline parallelization per tree, §4.3 inside-component multithreading, and
concurrent execution of independent trees (the dataflow task planner).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .component import (Component, ComponentType, SinkComponent,
                        SourceComponent)
from .graph import Dataflow
from .partitioner import ExecutionTreeGraph, partition
from .pipeline import TreePipeline
from .planner import PipelinePlan, build_plan, choose_degree
from .shared_cache import GLOBAL_CACHE_STATS, SharedCache


@dataclass
class EngineRun:
    wall_time: float
    copies: int
    bytes_copied: int
    engine: str
    activity_times: Dict[str, float] = field(default_factory=dict)
    trees: Optional[List[List[str]]] = None
    plans: Dict[int, PipelinePlan] = field(default_factory=dict)

    def summary(self) -> str:
        return (f"[{self.engine}] wall={self.wall_time:.3f}s copies={self.copies} "
                f"bytes_copied={self.bytes_copied/1e6:.1f}MB")


# --------------------------------------------------------------------------
#  Ordinary engine (baseline)
# --------------------------------------------------------------------------
class OrdinaryEngine:
    """Separate input/output caches, copy on every edge, sequential."""

    def __init__(self, flow: Dataflow, chunk_rows: int = 65536):
        self.flow = flow
        self.chunk_rows = chunk_rows

    def _push(self, name: str, cache: SharedCache,
              states: Dict[str, list]) -> None:
        comp = self.flow.component(name)
        if comp.ctype in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK):
            comp.accumulate(states[name], cache)
            return
        outs = comp.process(cache, shared=False)
        self._route(name, outs, states)

    def _route(self, name: str, outs: List[SharedCache],
               states: Dict[str, list]) -> None:
        succs = self.flow.succ(name)
        per_port = len(outs) == len(succs) and len(outs) > 1
        for i, u in enumerate(succs):
            out = outs[i] if per_port else outs[0]
            # separate-cache scheme: copy output cache -> downstream input cache
            copied = out.copy()
            GLOBAL_CACHE_STATS.record(out)
            self._push(u, copied, states)

    def run(self) -> EngineRun:
        self.flow.validate()
        self.flow.reset_stats()
        before = GLOBAL_CACHE_STATS.snapshot()
        t_start = time.perf_counter()
        states: Dict[str, list] = {
            n: c.new_state() for n, c in self.flow.vertices.items()
            if c.ctype in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK)}
        # stream every source, chunk by chunk
        for sname in self.flow.sources():
            src = self.flow.component(sname)
            if isinstance(src, SourceComponent):
                for chunk in src.chunks(self.chunk_rows):
                    self._route(sname, [chunk], states)
            else:
                raise TypeError(f"source {sname!r} is not a SourceComponent")
        # finalize block/semi-block components in topological order
        for name in self.flow.topo_order():
            comp = self.flow.component(name)
            if comp.ctype in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK):
                out = comp.finish(states[name])
                self._route(name, [out], states)
        wall = time.perf_counter() - t_start
        after = GLOBAL_CACHE_STATS.snapshot()
        return EngineRun(
            wall_time=wall,
            copies=after["copies"] - before["copies"],
            bytes_copied=after["bytes_copied"] - before["bytes_copied"],
            engine="ordinary",
            activity_times={n: c.busy_time for n, c in self.flow.vertices.items()})


# --------------------------------------------------------------------------
#  Optimized engine (the paper's framework)
# --------------------------------------------------------------------------
@dataclass
class OptimizeOptions:
    shared_cache: bool = True          # §3 shared caching scheme
    num_splits: int = 8                # m  — horizontal splits of root output
    pipeline_degree: Optional[int] = None  # m' — in-flight bound; None => m
    pipelined: bool = True             # False => sequential (non-pipeline)
    mt_threads: Dict[str, int] = field(default_factory=dict)  # §4.3 per component
    concurrent_trees: bool = True      # dataflow task planner concurrency
    chunk_rows: Optional[int] = None   # source chunking; None => total/num_splits


class OptimizedEngine:
    def __init__(self, flow: Dataflow, options: Optional[OptimizeOptions] = None):
        self.flow = flow
        self.options = options or OptimizeOptions()
        self.g_tau: Optional[ExecutionTreeGraph] = None
        # tree_id -> list of (src_tree_id, split_index, cache)
        self._inputs: Dict[int, List[Tuple[int, int, SharedCache]]] = {}
        self._inputs_lock = threading.Lock()
        self._root2tree: Dict[str, int] = {}

    # ----------------------------------------------------------- deliveries
    def _deliver(self, dst_root: str, cache: SharedCache, split_index: int,
                 src_tree: int) -> None:
        tid = self._root2tree[dst_root]
        with self._inputs_lock:
            self._inputs[tid].append((src_tree, split_index, cache))

    # ----------------------------------------------------------- tree runs
    def _tree_splits(self, tree, opts: OptimizeOptions):
        """Produce the horizontal splits of the root output (medium-level
        partitioning)."""
        root = self.flow.component(tree.root)
        if isinstance(root, SourceComponent):
            total = root.total_rows()
            chunk = opts.chunk_rows or max(1, -(-total // max(opts.num_splits, 1)))
            def gen():
                for i, c in enumerate(root.chunks(chunk)):
                    c.split_index = i
                    yield c
            return gen()
        # block / semi-block root: accumulate delivered caches, finish, split
        entries = sorted(self._inputs[tree.tree_id], key=lambda e: (e[0], e[1]))
        state = root.new_state()
        for _, _, cache in entries:
            root.accumulate(state, cache)
        out = root.finish(state)
        return out.split(opts.num_splits)

    def _run_tree(self, tree, pool: Optional[ThreadPoolExecutor]) -> None:
        opts = self.options
        tp = TreePipeline(self.flow, tree, self.g_tau.tree_of, self._deliver,
                          mt_config=opts.mt_threads, pool=pool,
                          shared=opts.shared_cache)
        splits = self._tree_splits(tree, opts)
        if not opts.shared_cache:
            # separate-cache mode inside the tree: copy on every hop
            splits = (self._copy_split(s) for s in splits)
        if opts.pipelined:
            m_prime = opts.pipeline_degree or opts.num_splits
            tp.run(splits, m_prime=m_prime, process_root=False)
        else:
            tp.run_sequential(splits, process_root=False)

    @staticmethod
    def _copy_split(s: SharedCache) -> SharedCache:
        c = s.copy()
        GLOBAL_CACHE_STATS.record(s)
        c.split_index = s.split_index
        return c

    # ---------------------------------------------------------------- run
    def run(self) -> EngineRun:
        opts = self.options
        self.flow.validate()
        self.flow.reset_stats()
        self.g_tau = partition(self.flow)
        self._inputs = {t.tree_id: [] for t in self.g_tau.trees}
        self._root2tree = {t.root: t.tree_id for t in self.g_tau.trees}

        mt_max = max([1] + list(opts.mt_threads.values()))
        pool = ThreadPoolExecutor(max_workers=mt_max) if mt_max > 1 else None

        from .scheduler import run_tree_graph

        before = GLOBAL_CACHE_STATS.snapshot()
        t_start = time.perf_counter()
        try:
            run_tree_graph(self.g_tau,
                           lambda tree: self._run_tree(tree, pool),
                           concurrent=opts.concurrent_trees)
        finally:
            if pool is not None:
                pool.shutdown()
        wall = time.perf_counter() - t_start
        after = GLOBAL_CACHE_STATS.snapshot()
        return EngineRun(
            wall_time=wall,
            copies=after["copies"] - before["copies"],
            bytes_copied=after["bytes_copied"] - before["bytes_copied"],
            engine="optimized",
            activity_times={n: c.busy_time for n, c in self.flow.vertices.items()},
            trees=[list(t.members) for t in self.g_tau.trees])
