"""Execution engines.

`OrdinaryEngine` — the paper's baseline (Figure 3): every component owns a
separate output cache; on EVERY edge the rows are physically copied into the
downstream component's input cache; execution is sequential.

`OptimizedEngine` — the paper's framework: Algorithm-1 partitioning into
execution trees, shared caching inside each tree (zero copies), Algorithm-2
pipeline parallelization per tree, §4.3 inside-component multithreading, and
concurrent execution of independent trees (the dataflow task planner).  All
work — tree coordination, pipeline split consumers and §4.3 row ranges —
runs on ONE shared, size-bounded worker pool (executor.py) sized by the
runtime planner.

`StreamingEngine` — `OptimizedEngine` with inter-tree split streaming turned
on: bounded channels replace accumulate-then-start on every tree->tree edge,
so a downstream tree whose root is row-synchronized (an explicit
StageBoundary) consumes splits as they arrive and overlaps with its
upstream; block / semi-block roots keep accumulate-then-finish semantics.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

from ..obs import trace as obs_trace
from . import config, faults
from .backend import Backend, resolve_backend
from .component import ComponentType, SourceComponent
from .executor import SharedWorkerPool, StreamingExecutor
from .graph import Dataflow
from .metadata import MetadataStore
from .partitioner import ExecutionTreeGraph, partition
from .planner import PipelinePlan, RuntimePlan, build_plan, plan_runtime
from .shared_cache import (GLOBAL_ARENA, SharedCache, cache_stats_scope,
                           record_copy)

#: environment switch for segment fusion when OptimizeOptions.fuse_segments
#: is left unset (the CI fusion leg runs the whole suite under REPRO_FUSION=1;
#: typed accessor: ``core.config.fusion_default``)
FUSION_ENV_VAR = config.ENV_FUSION


@dataclass
class EngineRun:
    wall_time: float
    copies: int
    bytes_copied: int
    engine: str
    backend: str = "numpy"
    h2d_bytes: int = 0              # host->device bytes moved by the backend
    d2h_bytes: int = 0              # device->host bytes (sinks / host merges)
    h2d_transfers: int = 0          # discrete host->device crossings
    d2h_transfers: int = 0          # discrete device->host crossings
    #: total backend dispatches (Component.calls summed over the flow) — the
    #: per-chunk activity-call count segment fusion collapses
    dispatch_calls: int = 0
    # CacheArena traffic attributed to this run
    arena_hits: int = 0
    arena_misses: int = 0
    arena_bytes_reused: int = 0
    activity_times: Dict[str, float] = field(default_factory=dict)
    trees: Optional[List[List[str]]] = None
    plans: Dict[int, PipelinePlan] = field(default_factory=dict)
    runtime_plan: Optional[RuntimePlan] = None
    streamed_edges: List[Tuple[int, int]] = field(default_factory=list)
    pool_stats: Dict[str, int] = field(default_factory=dict)
    # fault tolerance: transient retries taken, degradation-ladder fallbacks
    # and injected faults attributed to this run (all zero on a no-fault run)
    retries: int = 0
    degradations: int = 0
    faults_injected: int = 0
    #: per-fallback detail (core.faults.Degradation.spec() dicts)
    degradation_events: List[Dict[str, object]] = field(default_factory=list)
    #: sharded execution (core/shard): shard count the run actually used
    #: (1 = serial) and the source rows each shard processed
    shards: int = 1
    shard_rows: List[int] = field(default_factory=list)
    # adaptive path (optimize_level=2): graph rewrites applied before the run
    rewrites: List[Dict[str, str]] = field(default_factory=list)
    # rewrites the optimizer REFUSED for safety (with reasons) — refusals
    # mentioning an "undeclared" read/write set mark optimizations a lambda
    # predicate silently disabled (the DSL derives provenance instead)
    refusals: List[Dict[str, str]] = field(default_factory=list)
    # run identity (joins this run to its metadata / bench-JSON / trace
    # artifacts) + per-run observability (repro.obs)
    run_id: str = field(default_factory=obs_trace.new_run_id)
    created: str = field(default_factory=obs_trace.iso_now)
    git_sha: Optional[str] = field(default_factory=obs_trace.git_sha)
    #: MetricsRegistry.snapshot() of the run's tracer ({} when tracing off);
    #: its counters reconcile exactly with the CacheStats fields above
    metrics: Dict[str, object] = field(default_factory=dict)
    #: exported Chrome-trace/Perfetto file (REPRO_TRACE=1), else None
    trace_file: Optional[str] = None

    def summary(self) -> str:
        s = (f"[{self.engine}/{self.backend}] wall={self.wall_time:.3f}s "
             f"copies={self.copies} "
             f"bytes_copied={self.bytes_copied/1e6:.1f}MB")
        if self.h2d_bytes or self.d2h_bytes:
            s += (f" h2d={self.h2d_bytes/1e6:.1f}MB/{self.h2d_transfers}x"
                  f" d2h={self.d2h_bytes/1e6:.1f}MB/{self.d2h_transfers}x")
        if self.arena_hits or self.arena_misses:
            s += (f" arena={self.arena_hits}h/{self.arena_misses}m/"
                  f"{self.arena_bytes_reused/1e6:.1f}MB")
        if self.rewrites:
            s += f" rewrites={len(self.rewrites)}"
        if self.refusals:
            s += f" refusals={len(self.refusals)}"
        if self.retries or self.degradations or self.faults_injected:
            s += (f" faults={self.faults_injected} retries={self.retries} "
                  f"degradations={self.degradations}")
        if self.shards > 1:
            s += f" shards={self.shards}"
        return s

    def spec(self) -> dict:
        """Metadata-store / benchmark-JSON representation: the scalar
        instrumentation of one run (no plan/tree objects)."""
        return {"engine": self.engine, "backend": self.backend,
                "wall_time": self.wall_time,
                "copies": self.copies, "bytes_copied": self.bytes_copied,
                "h2d_transfers": self.h2d_transfers,
                "h2d_bytes": self.h2d_bytes,
                "d2h_transfers": self.d2h_transfers,
                "d2h_bytes": self.d2h_bytes,
                "dispatch_calls": self.dispatch_calls,
                "arena_hits": self.arena_hits,
                "arena_misses": self.arena_misses,
                "arena_bytes_reused": self.arena_bytes_reused,
                "retries": self.retries,
                "degradations": self.degradations,
                "faults_injected": self.faults_injected,
                "shards": self.shards,
                "shard_rows": list(self.shard_rows),
                "degradation_events": list(self.degradation_events),
                "rewrites": list(self.rewrites),
                "refusals": list(self.refusals),
                "run_id": self.run_id, "created": self.created,
                "git_sha": self.git_sha,
                "metrics": dict(self.metrics),
                "trace_file": self.trace_file}


def _assign_backend(flow: Dataflow, backend: Backend) -> None:
    """Point every component of the flow at the run's operator backend."""
    for comp in flow.vertices.values():
        comp.backend = backend


def _dispatch_calls(flow: Dataflow) -> int:
    return sum(c.calls for c in flow.vertices.values())


def _run_counters(run: EngineRun, snap: Dict[str, int]) -> None:
    """Fill an EngineRun's cache/arena counters from a per-run scope
    snapshot (exact attribution — no global-diff races)."""
    run.copies = snap["copies"]
    run.bytes_copied = snap["bytes_copied"]
    run.h2d_bytes = snap["h2d_bytes"]
    run.d2h_bytes = snap["d2h_bytes"]
    run.h2d_transfers = snap["h2d_transfers"]
    run.d2h_transfers = snap["d2h_transfers"]
    run.arena_hits = snap["arena_hits"]
    run.arena_misses = snap["arena_misses"]
    run.arena_bytes_reused = snap["arena_bytes_reused"]
    run.retries = snap["retries"]
    run.degradations = snap["degradations"]
    run.faults_injected = snap["faults_injected"]


def _finish_obs(tracer, run: EngineRun,
                pool_stats: Optional[Dict[str, int]] = None,
                channel_hwm: Optional[int] = None) -> None:
    """End-of-run observability: derive the gauges (arena hit rate, pool
    utilization, channel high-water), attach the metric snapshot to the run
    and export the trace (no-op when tracing is off)."""
    if tracer is None:
        return
    m = tracer.metrics
    attempts = run.arena_hits + run.arena_misses
    if attempts:
        m.gauge_set("arena_hit_rate", run.arena_hits / attempts)
    m.gauge_set("arena_pooled_bytes", GLOBAL_ARENA.pooled_bytes)
    if pool_stats:
        m.gauge_set("pool_width", pool_stats.get("width", 0))
        m.gauge_set("pool_threads_hwm", pool_stats.get("threads_hwm", 0))
        m.gauge_set("pool_tasks_run", pool_stats.get("tasks_run", 0))
        width = pool_stats.get("width") or 0
        if width:
            m.gauge_set("pool_utilization",
                        pool_stats.get("runnable_hwm", 0) / width)
    if channel_hwm is not None:
        m.gauge_set("channel_occupancy_hwm", channel_hwm)
    run.metrics = m.snapshot()
    run.trace_file = obs_trace.export_run(
        tracer, meta={"run_id": run.run_id, "created": run.created,
                      "git_sha": run.git_sha, "engine": run.engine,
                      "backend": run.backend, "wall_s": run.wall_time})


# --------------------------------------------------------------------------
#  Ordinary engine (baseline)
# --------------------------------------------------------------------------
class OrdinaryEngine:
    """Separate input/output caches, copy on every edge, sequential."""

    def __init__(self, flow: Dataflow, chunk_rows: int = 65536,
                 backend: Optional[str] = None):
        self.flow = flow
        self.chunk_rows = chunk_rows
        self.backend = backend        # None => REPRO_BACKEND env / "numpy"

    def _push(self, name: str, cache: SharedCache,
              states: Dict[str, list]) -> None:
        comp = self.flow.component(name)
        if comp.ctype in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK):
            comp.accumulate(states[name], cache)
            return
        outs = comp.process(cache, shared=False)
        self._route(name, outs, states)
        cache.recycle()      # downstream got copies; this cache is consumed

    def _route(self, name: str, outs: List[SharedCache],
               states: Dict[str, list]) -> None:
        succs = self.flow.succ(name)
        per_port = len(outs) == len(succs) and len(outs) > 1
        for i, u in enumerate(succs):
            out = outs[i] if per_port else outs[0]
            # separate-cache scheme: copy output cache -> downstream input cache
            copied = out.copy()
            record_copy(out)
            self._push(u, copied, states)

    def run(self) -> EngineRun:
        self.flow.validate()
        self.flow.reset_stats()
        bk = resolve_backend(self.backend)
        _assign_backend(self.flow, bk)
        with obs_trace.run_scope(flow=self.flow.name, engine="ordinary",
                                 backend=bk.name) as tracer:
            t_start = time.perf_counter()
            with cache_stats_scope() as stats, obs_trace.measured(tracer), \
                    obs_trace.span("phase", "execute"):
                states: Dict[str, list] = {
                    n: c.new_state() for n, c in self.flow.vertices.items()
                    if c.ctype in (ComponentType.BLOCK, ComponentType.SEMI_BLOCK)}
                # stream every source, chunk by chunk
                for sname in self.flow.sources():
                    src = self.flow.component(sname)
                    if isinstance(src, SourceComponent):
                        for chunk in src.chunks(self.chunk_rows):
                            self._route(sname, [chunk], states)
                            chunk.recycle()
                    else:
                        raise TypeError(
                            f"source {sname!r} is not a SourceComponent")
                # finalize block/semi-block components in topological order
                for name in self.flow.topo_order():
                    comp = self.flow.component(name)
                    if comp.ctype in (ComponentType.BLOCK,
                                      ComponentType.SEMI_BLOCK):
                        out = comp.finish(states[name])
                        self._route(name, [out], states)
                        out.recycle()
            wall = time.perf_counter() - t_start
            run = EngineRun(
                wall_time=wall, copies=0, bytes_copied=0,
                engine="ordinary",
                backend=bk.name,
                dispatch_calls=_dispatch_calls(self.flow),
                activity_times={n: c.busy_time
                                for n, c in self.flow.vertices.items()})
            _run_counters(run, stats.snapshot())
            _finish_obs(tracer, run)
        return run


# --------------------------------------------------------------------------
#  Optimized engine (the paper's framework on the streaming runtime)
# --------------------------------------------------------------------------
@dataclass
class OptimizeOptions:
    shared_cache: bool = True          # §3 shared caching scheme
    num_splits: int = 8                # m  — horizontal splits of root output
    pipeline_degree: Optional[int] = None  # m' — in-flight bound; None => m
    pipelined: bool = True             # False => sequential (non-pipeline)
    mt_threads: Dict[str, int] = field(default_factory=dict)  # §4.3 per component
    concurrent_trees: bool = True      # dataflow task planner concurrency
    chunk_rows: Optional[int] = None   # source chunking; None => total/num_splits
    streaming: bool = False            # inter-tree split streaming (executor.py)
    pool_width: Optional[int] = None   # shared pool size; None => planner
    channel_capacity: Optional[int] = None  # per-edge depth; None => planner
    cores: Optional[int] = None        # cap pool width at core count if set
    backend: Optional[str] = None      # operator backend ("numpy"/"jax");
    #                                    None => REPRO_BACKEND env / "numpy"
    #: 1 = the paper's static framework (partition + plan once, up front);
    #: 2 = cost-based adaptive: calibrate on a source prefix, rewrite the
    #: flow from measured statistics (core/optimizer.py), then re-partition
    #: and re-plan with observed per-edge bytes and activity times.
    optimize_level: int = 1
    #: source-prefix rows for the optimize_level=2 calibration run
    calibration_rows: int = 4096
    #: segment fusion: collapse maximal row-synchronized chains into single
    #: compiled-kernel activities (optimizer.fuse_segments_flow).  None =>
    #: follow the REPRO_FUSION env var; applies at every optimize level.
    fuse_segments: Optional[bool] = None
    #: sharded execution (core/shard): partition the source rows over N
    #: shards, run the full per-shard flow, merge partials once at the
    #: coordinator — sinks stay byte-identical to serial.  None => follow
    #: REPRO_SHARDS (default 1 = serial); 0 = auto-pick from calibration
    #: signals (planner.choose_shards).
    shards: Optional[int] = None
    #: shard worker route: "auto" | "process" | "mesh" | "inline".  None =>
    #: follow REPRO_SHARD_IMPL (default "auto").
    shard_impl: Optional[str] = None

    def fusion_enabled(self) -> bool:
        if self.fuse_segments is not None:
            return bool(self.fuse_segments)
        return config.fusion_default()


class OptimizedEngine:
    def __init__(self, flow: Dataflow, options: Optional[OptimizeOptions] = None,
                 metadata: Optional["MetadataStore"] = None):
        self.flow = flow
        self.options = options or OptimizeOptions()
        self.metadata = metadata       # §2 store: records flow/partition/plan
        self.g_tau: Optional[ExecutionTreeGraph] = None
        self.runtime_plan: Optional[RuntimePlan] = None

    @property
    def engine_name(self) -> str:
        return "streaming" if self.options.streaming else "optimized"

    # ---------------------------------------------------- adaptive planning
    def _adaptive_rewrite(self, bk: Backend, opts: OptimizeOptions):
        """optimize_level=2: calibrate, rewrite the flow from measured
        statistics, re-partition + re-plan with observed costs.  Returns
        (effective options, applied rewrites, refused rewrites)."""
        from .optimizer import (CostBasedOptimizer, measured_edge_bytes,
                                run_calibration, suggest_pipeline_degree)
        streaming = opts.streaming and opts.concurrent_trees
        # BEFORE: the static partitioning + plan the paper's framework uses
        before_tau = partition(self.flow)
        before_plan = plan_runtime(
            self.flow, before_tau,
            num_splits=opts.num_splits,
            m_prime=opts.pipeline_degree or opts.num_splits,
            mt_threads=opts.mt_threads, cores=opts.cores,
            pool_width=opts.pool_width,
            channel_capacity=opts.channel_capacity,
            streaming=streaming, backend=bk)
        with obs_trace.span("phase", "calibrate",
                            sample_rows=opts.calibration_rows):
            # calibration is idempotent (stats reset before/after, sinks
            # never written), so a transient mid-calibration failure just
            # re-runs the whole sample pass
            stats = faults.retry_call(
                lambda: run_calibration(self.flow,
                                        sample_rows=opts.calibration_rows,
                                        backend=bk),
                where=f"calibrate.{self.flow.name}")
        optimizer = CostBasedOptimizer(self.flow, stats, streaming=streaming,
                                       fuse_segments=opts.fusion_enabled())
        with obs_trace.span("phase", "optimize"):
            rewrites = optimizer.optimize()
        _assign_backend(self.flow, bk)     # rewrites may add components
        with obs_trace.span("phase", "plan"):
            self.g_tau = partition(self.flow)
            m_prime = (opts.pipeline_degree
                       or suggest_pipeline_degree(stats, opts.num_splits,
                                                  cores=opts.cores))
            self.runtime_plan = plan_runtime(
                self.flow, self.g_tau,
                num_splits=opts.num_splits, m_prime=m_prime,
                mt_threads=opts.mt_threads, cores=opts.cores,
                pool_width=opts.pool_width,
                channel_capacity=opts.channel_capacity,
                streaming=streaming, backend=bk,
                edge_bytes_override=measured_edge_bytes(self.flow, self.g_tau,
                                                        stats))
        if self.metadata is not None:
            self.metadata.register_statistics(self.flow, stats)
            self.metadata.register_adaptive(
                self.flow, stats=stats, rewrites=rewrites,
                before_partition=before_tau, before_plan=before_plan,
                after_partition=self.g_tau, after_plan=self.runtime_plan)
        # the executor reads m' from the options: hand it a private copy so
        # the caller's options object is never mutated
        return (replace(opts, pipeline_degree=m_prime), rewrites,
                optimizer.refusals)

    # ----------------------------------------------------------- fault replay
    def _reset_for_retry(self) -> None:
        """Return the flow to a runnable state between run-level retry
        attempts: clear the pipeline's order/busy bookkeeping on every
        component and drop any partial output a sink collected during the
        failed attempt (replaying into a half-filled sink would duplicate
        rows).  Accumulator state is per-executor (``new_state`` per run),
        so it needs no reset here."""
        for comp in self.flow.vertices.values():
            comp.next_split = 0
            comp.busy = False
            if comp.ctype is ComponentType.SINK and hasattr(comp, "clear"):
                comp.clear()

    # ---------------------------------------------------------------- run
    def run(self) -> EngineRun:
        opts = self.options
        self.flow.validate()
        self.flow.reset_stats()
        bk = resolve_backend(opts.backend)
        _assign_backend(self.flow, bk)      # before planning: est_output_bytes
        with obs_trace.run_scope(flow=self.flow.name, engine=self.engine_name,
                                 backend=bk.name) as tracer:
            rewrites, refusals = [], []
            if opts.optimize_level >= 2:
                opts, rewrites, refusals = self._adaptive_rewrite(bk, opts)
            else:
                if opts.fusion_enabled():
                    from .optimizer import fuse_segments_flow
                    rewrites = fuse_segments_flow(self.flow)
                    _assign_backend(self.flow, bk)   # fusion adds components
                with obs_trace.span("phase", "plan"):
                    self.g_tau = partition(self.flow)
                    m_prime = opts.pipeline_degree or opts.num_splits
                    self.runtime_plan = plan_runtime(
                        self.flow, self.g_tau,
                        num_splits=opts.num_splits, m_prime=m_prime,
                        mt_threads=opts.mt_threads, cores=opts.cores,
                        pool_width=opts.pool_width,
                        channel_capacity=opts.channel_capacity,
                        streaming=opts.streaming and opts.concurrent_trees,
                        backend=bk)
            if self.metadata is not None:
                self.metadata.register_flow(self.flow)
                self.metadata.register_partitioning(self.flow, self.g_tau)
                self.metadata.register_runtime_plan(self.flow,
                                                    self.runtime_plan)

            t_start = time.perf_counter()
            # Run-level retry: a transient failure that escalated past
            # chunk-level replay (source draw, accumulate, sink write, edge
            # transfer) aborts the executor; the whole run replays on a
            # fresh executor after the flow's transient state is reset.
            # The stats scope / tracer / span stay OUTSIDE the loop so
            # retry counters and failed-attempt work attribute to this run.
            sres = None
            attempt, delay = 0, config.retry_backoff()
            with cache_stats_scope() as stats, obs_trace.measured(tracer), \
                    obs_trace.span("phase", "execute"), \
                    faults.fault_recorder() as frec:
                n_shards = (opts.shards if opts.shards is not None
                            else config.shards())
                if n_shards != 1:
                    # planned inside the run's scopes so a shard_plan
                    # degradation (unshardable flow) attributes to this run
                    from .shard import plan_shards
                    shard_plan = plan_shards(
                        self.flow, self.g_tau, n_shards,
                        opts.shard_impl or config.shard_impl(), opts, bk)
                else:
                    shard_plan = None
                if shard_plan is not None:
                    # sharded path: per-shard transient replay (inside the
                    # runner) supersedes run-level retry
                    from .shard import ShardRunner
                    sres = ShardRunner(self.flow, self.g_tau, opts,
                                       self.runtime_plan, shard_plan,
                                       tracer=tracer).execute()
                    pool_stats = sres.pool_stats
                    streamed_edges = sres.streamed_edges
                    channel_hwm = sres.channel_hwm
                else:
                    while True:
                        executor = StreamingExecutor(self.flow, self.g_tau,
                                                     opts, self.runtime_plan)
                        try:
                            executor.execute()
                            break
                        except BaseException as e:
                            if (faults.classify(e) != "transient"
                                    or attempt >= config.retry_max()):
                                raise
                            faults.record_retry(f"run.{self.flow.name}",
                                                attempt, delay)
                            self._reset_for_retry()
                            if delay > 0.0:
                                time.sleep(delay)
                            delay = min(delay * 2.0 if delay else 0.0,
                                        faults.RETRY_BACKOFF_CAP_S)
                            attempt += 1
                        finally:
                            pool_stats = executor.pool.stats()
                            executor.shutdown()
                    streamed_edges = list(executor.streamed_edges)
                    channel_hwm = executor.channel_hwm()
            wall = time.perf_counter() - t_start
            run = EngineRun(
                wall_time=wall, copies=0, bytes_copied=0,
                engine=self.engine_name,
                backend=bk.name,
                dispatch_calls=_dispatch_calls(self.flow),
                activity_times={n: c.busy_time
                                for n, c in self.flow.vertices.items()},
                trees=[list(t.members) for t in self.g_tau.trees],
                runtime_plan=self.runtime_plan,
                streamed_edges=streamed_edges,
                pool_stats=pool_stats,
                degradation_events=[d.spec() for d in frec.degradations],
                rewrites=[r.spec() for r in rewrites],
                refusals=[r.spec() for r in refusals])
            snap = stats.snapshot()
            if sres is not None:
                # process-route worker counters were already absorbed into
                # this scope (shared_cache.absorb_external), so snap equals
                # the exact sum over all shards on every route
                run.shards = sres.shards
                run.shard_rows = list(sres.shard_rows)
                # dispatch counts live on Component.calls; process-route
                # shard passes ran on worker flow copies, so fold their
                # shipped totals in — inline passes already hit self.flow
                run.dispatch_calls += sres.worker_dispatch
            _run_counters(run, snap)
            _finish_obs(tracer, run, pool_stats=pool_stats,
                        channel_hwm=channel_hwm)
            if self.metadata is not None:
                self.metadata.register_run(self.flow, run)
        return run


class StreamingEngine(OptimizedEngine):
    """OptimizedEngine with inter-tree split streaming enabled."""

    def __init__(self, flow: Dataflow, options: Optional[OptimizeOptions] = None,
                 metadata: Optional["MetadataStore"] = None):
        options = replace(options or OptimizeOptions(), streaming=True)
        super().__init__(flow, options, metadata=metadata)


# --------------------------------------------------------------------------
#  Serving engine (resident micro-batch loop for Session.serve)
# --------------------------------------------------------------------------
class ServingEngine:
    """Resident execution loop behind ``Session.serve``: partition and plan
    ONCE (on the first tick, when the ticking source has data to size
    against), keep one ``SharedWorkerPool`` alive across micro-batches, and
    run each tick as a fresh — but cheap — ``StreamingExecutor`` over the
    SAME flow objects.  Because compiled segment runners, device-resident
    DimTables, jitted DSL expressions and arena buffers all live on the
    components (or the global arena), not on the executor, warm ticks reuse
    every piece of state a batch engine rebuilds per run.

    Terminal ``Aggregate`` components are switched into serving mode
    (incremental per-group partials, upsert deltas) for the lifetime of the
    loop; ``close()`` switches them back and releases the pool."""

    engine_name = "serving"

    def __init__(self, flow: Dataflow,
                 options: Optional[OptimizeOptions] = None,
                 metadata: Optional["MetadataStore"] = None):
        self.flow = flow
        self.options = options or OptimizeOptions()
        self.metadata = metadata
        self.g_tau: Optional[ExecutionTreeGraph] = None
        self.runtime_plan: Optional[RuntimePlan] = None
        self.backend: Optional[Backend] = None
        self.pool: Optional[SharedWorkerPool] = None
        self.tracer = None
        self.ticks = 0
        self._started = False
        self._closed = False
        self._serving_aggs: list = []

    # ------------------------------------------------------------ validation
    def _validate_serving_flow(self) -> None:
        """Serving supports row-synchronized chains plus TERMINAL aggregates
        (feeding sinks only).  Other block/semi-block components (Sort,
        Union, Merge) have no incremental upsert semantics — their finish()
        needs the whole input, which an unbounded source never yields."""
        for name, comp in self.flow.vertices.items():
            if hasattr(comp, "begin_serving"):
                bad = [u for u in self.flow.succ(name)
                       if self.flow.component(u).ctype
                       is not ComponentType.SINK]
                if bad:
                    raise ValueError(
                        f"serve(): aggregate {name!r} must feed sinks only "
                        f"(feeds {bad}) — per-tick upsert deltas cannot "
                        f"drive further blocking components")
            elif comp.ctype in (ComponentType.BLOCK,
                                ComponentType.SEMI_BLOCK):
                raise ValueError(
                    f"serve(): {type(comp).__name__} {name!r} is a "
                    f"{comp.ctype.value} component without incremental "
                    f"semantics; serving flows support row-synchronized "
                    f"chains and terminal Aggregates")

    # ----------------------------------------------------------- first tick
    def _start(self) -> None:
        opts = self.options
        if opts.optimize_level >= 2:
            raise ValueError(
                "serve() supports optimize_level<=1: the adaptive optimizer "
                "calibrates on a bounded source prefix, which an unbounded "
                "ticking source does not have")
        if opts.shards is not None and opts.shards > 1:
            # explicit request only — ambient REPRO_SHARDS is ignored here,
            # since the resident tick loop is already incremental and the
            # multi-pass shard protocol assumes a bounded batch input
            raise ValueError("serve() does not support sharded execution; "
                             "drop shards= for serving sessions")
        self.flow.validate()
        self.flow.reset_stats()
        bk = self.backend = resolve_backend(opts.backend)
        _assign_backend(self.flow, bk)
        if opts.fusion_enabled():
            from .optimizer import fuse_segments_flow
            fuse_segments_flow(self.flow)
            _assign_backend(self.flow, bk)   # fusion adds components
        self._validate_serving_flow()
        with obs_trace.span("phase", "plan"):
            self.g_tau = partition(self.flow)
            self.runtime_plan = plan_runtime(
                self.flow, self.g_tau,
                num_splits=opts.num_splits,
                m_prime=opts.pipeline_degree or opts.num_splits,
                mt_threads=opts.mt_threads, cores=opts.cores,
                pool_width=opts.pool_width,
                channel_capacity=opts.channel_capacity,
                streaming=opts.streaming and opts.concurrent_trees,
                backend=bk)
        self.pool = SharedWorkerPool(self.runtime_plan.pool_width,
                                     name=f"{self.flow.name}-serve")
        for comp in self.flow.vertices.values():
            if hasattr(comp, "begin_serving"):
                comp.begin_serving()
                self._serving_aggs.append(comp)
        if self.metadata is not None:
            # the session registers once at start — NOT once per tick, which
            # would grow the store without bound under a resident loop
            self.metadata.register_flow(self.flow)
            self.metadata.register_partitioning(self.flow, self.g_tau)
            self.metadata.register_runtime_plan(self.flow, self.runtime_plan)
        self._started = True

    # ----------------------------------------------------------------- tick
    def tick(self, watermark_lag: Optional[float] = None) -> Dict[str, object]:
        """Run one micro-batch over the source's CURRENT table.  Returns the
        tick's wall time and its exact per-tick ``CacheStats`` snapshot."""
        if self._closed:
            raise RuntimeError("serving engine is closed")
        if self.tracer is None and (obs_trace.ACTIVE.get()
                                    or config.trace_enabled()):
            # ONE tracer for the whole serving session: per-tick spans land
            # in it and a single trace export happens at close() — a
            # per-tick export would rewrite the trace file every tick
            self.tracer = obs_trace.Tracer(name=self.flow.name,
                                           measuring=False)
            self.tracer.meta = {"flow": self.flow.name, "engine": "serving"}
        i = self.ticks
        with (obs_trace.trace_scope(self.tracer)
              if self.tracer is not None else nullcontext()):
            if not self._started:
                self._start()
            # per-tick split numbering restarts at zero: order-sensitive
            # components gate on next_split == cache.split_index, which is
            # monotone within one executor run only.  busy is cleared too so
            # an aborted tick can never deadlock the next one behind a flag
            # its dying task had no chance to release.
            for comp in self.flow.vertices.values():
                comp.next_split = 0
                comp.busy = False
            executor = StreamingExecutor(self.flow, self.g_tau, self.options,
                                         self.runtime_plan, pool=self.pool)
            t0 = time.perf_counter()
            with cache_stats_scope() as stats, \
                    obs_trace.measured(self.tracer), \
                    obs_trace.span("tick", f"tick-{i}", tick=i):
                try:
                    executor.execute()
                finally:
                    executor.shutdown()      # no-op: the pool is resident
            wall = time.perf_counter() - t0
        self.ticks += 1
        if self.tracer is not None:
            m = self.tracer.metrics
            m.inc("ticks")
            m.observe("tick_s", wall)
            if watermark_lag is not None:
                m.gauge_set("watermark_lag_s", watermark_lag)
                m.gauge_max("watermark_lag_s_max", watermark_lag)
        return {"tick": i, "wall_s": wall, "cache_stats": stats.snapshot()}

    # ---------------------------------------------------------------- close
    def close(self) -> Dict[str, object]:
        """End the serving session: aggregates leave serving mode (reusable
        for batch runs), the resident pool joins, the session trace exports
        once.  Idempotent."""
        summary: Dict[str, object] = {
            "engine": self.engine_name, "ticks": self.ticks,
            "backend": self.backend.name if self.backend else None}
        if self._closed:
            return summary
        self._closed = True
        for comp in self._serving_aggs:
            comp.end_serving()
        self._serving_aggs = []
        if self.pool is not None:
            self.pool.shutdown(wait=True)
        if self.tracer is not None:
            self.tracer.meta.update(summary)
            summary["metrics"] = self.tracer.metrics.snapshot()
            summary["trace_file"] = obs_trace.export_run(
                self.tracer, meta={"ticks": self.ticks})
        return summary
