"""Dataflow task planner (§2): when a dataflow is partitioned, the job is
generated into multiple tasks (one per execution tree) and the planner
executes them according to the dependency of the generated tasks.

Tree tasks run as coordination tasks on the run's shared ``SharedWorkerPool``
(executor.py) rather than a thread per tree.  Two gating modes:

- ``gate_on_upstream=True`` (the paper's semantics): a tree's task starts as
  soon as ALL upstream trees have finished — block / semi-block roots require
  the complete input.
- ``gate_on_upstream=False`` (streaming mode): every coordinator starts
  immediately; inter-tree dependencies are carried by the bounded split
  channels instead (closure of a channel == upstream completion), which is
  what lets row-synchronized stage boundaries overlap across trees.

Error handling: the first exception in any tree task trips the run-wide
``RunAbort`` — queued tasks are cancelled, blocked tasks wake and unwind —
and the ORIGINAL exception is re-raised promptly instead of being surfaced
only after every thread has joined.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

from .executor import RunAbort, SharedWorkerPool, TaskFuture
from .partitioner import ExecutionTree, ExecutionTreeGraph

RunTreeFn = Callable[[ExecutionTree], None]


def run_tree_graph(g_tau: ExecutionTreeGraph, run_tree: RunTreeFn,
                   concurrent: bool = True,
                   pool: Optional[SharedWorkerPool] = None,
                   abort: Optional[RunAbort] = None,
                   gate_on_upstream: bool = True) -> None:
    order = g_tau.topo_tree_order()
    if not concurrent:
        for tid in order:
            run_tree(g_tau.tree(tid))
        return

    own_pool = pool is None
    if own_pool:
        pool = SharedWorkerPool(width=max(2, len(order)),
                                name="tree-graph")
    if abort is None:
        abort = RunAbort()
    done: Dict[int, threading.Event] = {tid: threading.Event() for tid in order}
    # on abort, release every upstream waiter (they re-check abort right after)
    abort.subscribe(lambda: [evt.set() for evt in done.values()])

    def run_one(tid: int) -> None:
        try:
            if gate_on_upstream:
                for up in g_tau.upstream_trees(tid):
                    if not done[up].is_set():
                        with pool.blocking():
                            done[up].wait()
            abort.check()                       # cancelled while queued/gated
            run_tree(g_tau.tree(tid))
        except BaseException as e:  # noqa: BLE001 — recorded, re-raised below
            abort.trip(e)
        finally:
            done[tid].set()

    futures: List[TaskFuture] = [pool.submit(run_one, tid) for tid in order]
    try:
        for f in futures:
            f.wait()
    finally:
        if own_pool:
            pool.shutdown()
    if abort.aborted:
        raise abort.exc if abort.exc is not None else \
            RuntimeError("execution aborted")


def plan_schedule(g_tau: ExecutionTreeGraph) -> List[List[int]]:
    """Return the wave schedule: list of waves, each a list of tree ids that
    may run concurrently (all deps in earlier waves).  Raises ``ValueError``
    on a cyclic execution-tree graph."""
    remaining = {t.tree_id for t in g_tau.trees}
    waves: List[List[int]] = []
    finished: set = set()
    while remaining:
        wave = sorted(tid for tid in remaining
                      if all(up in finished for up in g_tau.upstream_trees(tid)))
        if not wave:
            raise ValueError("cycle in execution-tree graph")
        waves.append(wave)
        finished.update(wave)
        remaining.difference_update(wave)
    return waves
