"""Dataflow task planner (§2): when a dataflow is partitioned, the job is
generated into multiple tasks (one per execution tree) and the planner
executes them according to the dependency of the generated tasks.

A tree's task may start as soon as ALL upstream trees have finished (block /
semi-block semantics require the complete input); independent trees run
concurrently.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List

from .partitioner import ExecutionTree, ExecutionTreeGraph

RunTreeFn = Callable[[ExecutionTree], None]


def run_tree_graph(g_tau: ExecutionTreeGraph, run_tree: RunTreeFn,
                   concurrent: bool = True) -> None:
    order = g_tau.topo_tree_order()
    if not concurrent:
        for tid in order:
            run_tree(g_tau.tree(tid))
        return

    done: Dict[int, threading.Event] = {tid: threading.Event() for tid in order}
    errors: List[BaseException] = []
    err_lock = threading.Lock()

    def run_one(tid: int) -> None:
        try:
            for up in g_tau.upstream_trees(tid):
                done[up].wait()
            with err_lock:
                bail = bool(errors)
            if not bail:
                run_tree(g_tau.tree(tid))
        except BaseException as e:  # noqa: BLE001 — surfaced after join
            with err_lock:
                errors.append(e)
        finally:
            done[tid].set()

    threads = [threading.Thread(target=run_one, args=(tid,), daemon=True,
                                name=f"tree-task-{tid}") for tid in order]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    if errors:
        raise errors[0]


def plan_schedule(g_tau: ExecutionTreeGraph) -> List[List[int]]:
    """Return the wave schedule: list of waves, each a list of tree ids that
    may run concurrently (all deps in earlier waves)."""
    remaining = {t.tree_id for t in g_tau.trees}
    waves: List[List[int]] = []
    finished: set = set()
    while remaining:
        wave = sorted(tid for tid in remaining
                      if all(up in finished for up in g_tau.upstream_trees(tid)))
        if not wave:
            raise ValueError("cycle in execution-tree graph")
        waves.append(wave)
        finished.update(wave)
        remaining.difference_update(wave)
    return waves
