"""Figure 13 — CPU usage vs number of pipelines for 2/4/6/8 cores
(simulated on measured Q4.1 activity costs; paper boots maxcpus=n).

Emits CSV: cores,m,avg_cpu_usage
"""
from __future__ import annotations

from repro.core.simulate import cpu_usage_curve

from .common import activity_costs_from_sequential, ssb_data

DEGREES = [1, 2, 4, 8, 16, 32]


def run() -> list:
    data = ssb_data()
    costs, _ = activity_costs_from_sequential("Q4.1", data)
    per_act = list(costs.values())
    out = ["fig13.cores,m,avg_cpu_usage"]
    for cores in (2, 4, 6, 8):
        curve = cpu_usage_curve(per_act, DEGREES, cores=cores, t0=0.002,
                                switch_cost=0.004)
        for m in DEGREES:
            out.append(f"fig13.{cores},{m},{curve[m]:.3f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
