"""Kernel-level benchmark: reference-impl wall time on CPU (correctness
path) + the TPU roofline characteristics of each Pallas kernel at
production-relevant shapes (arithmetic intensity -> bound regime on v5e:
ridge = 197e12 / 819e9 ~ 241 FLOP/byte).

Emits CSV: kernel,shape,ref_ms_cpu,flops,bytes,intensity,v5e_bound
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_ref
from repro.kernels.mamba_scan import mamba_scan_ref
from repro.kernels.segment_sum import segment_sum_ref

RIDGE = 197e12 / 819e9


def _time(fn, *args):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    r = fn(*args)
    (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.perf_counter() - t0) * 1e3


def run() -> list:
    out = ["kernels.kernel,shape,ref_ms_cpu,flops,bytes,intensity,v5e_bound"]
    rng = np.random.default_rng(0)

    # flash attention: one mixtral prefill block per device
    B, S, Kh, G, hd = 1, 2048, 1, 4, 128
    q = jnp.asarray(rng.normal(size=(B, S, Kh, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Kh, hd)), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    ms = _time(f, q, k, v)
    flops = 4 * B * S * S * Kh * G * hd / 2        # causal half
    byts = (q.size + 2 * k.size + q.size) * 4
    inten = flops / byts
    out.append(f"kernels.flash_attention,B{B}xS{S}xh{Kh*G}xd{hd},"
               f"{ms:.1f},{flops:.2e},{byts:.2e},{inten:.0f},"
               f"{'compute' if inten > RIDGE else 'memory'}")

    # mamba scan: one falcon-mamba layer chunk per device
    Bt, T, d, N = 1, 2048, 512, 16
    delta = jnp.asarray(np.abs(rng.normal(size=(Bt, T, d))).clip(.01, 1),
                        jnp.float32)
    x = jnp.asarray(rng.normal(size=(Bt, T, d)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(Bt, T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(Bt, T, N)), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(d, N))) - .05, jnp.float32)
    h0 = jnp.zeros((Bt, d, N), jnp.float32)
    f = jax.jit(mamba_scan_ref)
    ms = _time(f, delta, x, Bm, C, A, h0)
    flops = Bt * T * d * N * 9                     # exp+3mul fma per (c,n)
    byts_fused = (delta.size + x.size + Bm.size + C.size
                  + Bt * T * d) * 4                # fused kernel traffic
    byts_naive = byts_fused + 2 * Bt * T * d * N * 4 * 2  # dA/dBx in HBM
    out.append(f"kernels.mamba_scan,B{Bt}xT{T}xd{d}xN{N},"
               f"{ms:.1f},{flops:.2e},{byts_fused:.2e},"
               f"{flops/byts_fused:.1f},memory")
    out.append(f"kernels.mamba_scan_unfused_traffic_ratio,,,,"
               f"{byts_naive/byts_fused:.1f}x,,")

    # segment sum: the paper's groupby (Fig-11 component 9)
    Nr, Cc, Gg = 1 << 20, 2, 512
    seg = jnp.asarray(rng.integers(0, Gg, Nr).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(Nr, Cc)), jnp.float32)
    f = jax.jit(lambda s, v: segment_sum_ref(s, v, Gg))
    ms = _time(f, seg, vals)
    flops = 2.0 * Nr * Gg * Cc                     # one-hot matmul form
    byts = (Nr * Cc + Nr + Gg * Cc) * 4
    out.append(f"kernels.segment_sum,N{Nr}xC{Cc}xG{Gg},"
               f"{ms:.1f},{flops:.2e},{byts:.2e},{flops/byts:.0f},"
               f"{'compute' if flops/byts > RIDGE else 'memory'}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
