"""Kernel-level benchmark: reference-impl wall time on CPU (correctness
path) + the TPU roofline characteristics of each Pallas kernel at
production-relevant shapes (arithmetic intensity -> bound regime on v5e:
ridge = 197e12 / 819e9 ~ 241 FLOP/byte).

Emits CSV: kernel,shape,ref_ms_cpu,flops,bytes,intensity,v5e_bound

``smoke()`` is the CI part: interpret-vs-reference equality sweeps for the
data kernels (hash_join probe, radix_groupby, segment_sum) — the Pallas
kernel BODY validated on CPU — plus the full intensity CSV written to
``KERNELS_<tag>.csv`` for upload next to the BENCH json.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_ref
from repro.kernels.hash_join import hash_build, hash_probe
from repro.kernels.mamba_scan import mamba_scan_ref
from repro.kernels.radix_groupby import radix_groupby
from repro.kernels.segment_sum import segment_sum, segment_sum_ref

RIDGE = 197e12 / 819e9


def _time(fn, *args):
    # one warmup call (compile + first run), then one timed call — the
    # result is evaluated ONCE per call (a tuple-check must not re-invoke fn)
    r = fn(*args)
    (r[0] if isinstance(r, tuple) else r).block_until_ready()
    t0 = time.perf_counter()
    r = fn(*args)
    (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.perf_counter() - t0) * 1e3


def run() -> list:
    out = ["kernels.kernel,shape,ref_ms_cpu,flops,bytes,intensity,v5e_bound"]
    rng = np.random.default_rng(0)

    # flash attention: one mixtral prefill block per device
    B, S, Kh, G, hd = 1, 2048, 1, 4, 128
    q = jnp.asarray(rng.normal(size=(B, S, Kh, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Kh, hd)), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    ms = _time(f, q, k, v)
    flops = 4 * B * S * S * Kh * G * hd / 2        # causal half
    byts = (q.size + 2 * k.size + q.size) * 4
    inten = flops / byts
    out.append(f"kernels.flash_attention,B{B}xS{S}xh{Kh*G}xd{hd},"
               f"{ms:.1f},{flops:.2e},{byts:.2e},{inten:.0f},"
               f"{'compute' if inten > RIDGE else 'memory'}")

    # mamba scan: one falcon-mamba layer chunk per device
    Bt, T, d, N = 1, 2048, 512, 16
    delta = jnp.asarray(np.abs(rng.normal(size=(Bt, T, d))).clip(.01, 1),
                        jnp.float32)
    x = jnp.asarray(rng.normal(size=(Bt, T, d)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(Bt, T, N)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(Bt, T, N)), jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=(d, N))) - .05, jnp.float32)
    h0 = jnp.zeros((Bt, d, N), jnp.float32)
    f = jax.jit(mamba_scan_ref)
    ms = _time(f, delta, x, Bm, C, A, h0)
    flops = Bt * T * d * N * 9                     # exp+3mul fma per (c,n)
    byts_fused = (delta.size + x.size + Bm.size + C.size
                  + Bt * T * d) * 4                # fused kernel traffic
    byts_naive = byts_fused + 2 * Bt * T * d * N * 4 * 2  # dA/dBx in HBM
    out.append(f"kernels.mamba_scan,B{Bt}xT{T}xd{d}xN{N},"
               f"{ms:.1f},{flops:.2e},{byts_fused:.2e},"
               f"{flops/byts_fused:.1f},memory")
    out.append(f"kernels.mamba_scan_unfused_traffic_ratio,,,,"
               f"{byts_naive/byts_fused:.1f}x,,")

    # segment sum: the paper's groupby (Fig-11 component 9)
    Nr, Cc, Gg = 1 << 20, 2, 512
    seg = jnp.asarray(rng.integers(0, Gg, Nr).astype(np.int32))
    vals = jnp.asarray(rng.normal(size=(Nr, Cc)), jnp.float32)
    f = jax.jit(lambda s, v: segment_sum_ref(s, v, Gg))
    ms = _time(f, seg, vals)
    flops = 2.0 * Nr * Gg * Cc                     # one-hot matmul form
    byts = (Nr * Cc + Nr + Gg * Cc) * 4
    out.append(f"kernels.segment_sum,N{Nr}xC{Cc}xG{Gg},"
               f"{ms:.1f},{flops:.2e},{byts:.2e},{flops/byts:.0f},"
               f"{'compute' if flops/byts > RIDGE else 'memory'}")

    # hash-join probe: the Lookup component at SSB dimension scale
    Dd, Np_ = 1 << 15, 1 << 20
    keys = np.sort(rng.choice(1 << 22, size=Dd, replace=False)).astype(np.int64)
    ht = hash_build((keys,))
    slot_keys = tuple(jnp.asarray(x) for x in ht["slot_keys"])
    slot_idx = jnp.asarray(ht["slot_idx"])
    probes = jnp.asarray(rng.integers(0, 1 << 22, Np_).astype(np.int64))
    ms = _time(lambda p: hash_probe(slot_keys, slot_idx, (p,),
                                    ht["max_probes"], impl="reference"),
               probes)
    mp = ht["max_probes"]
    flops = 1.0 * Np_ * (6 + 4 * mp)     # fmix32 + per-step cmp/mask chain
    byts = (Np_ + Np_ * mp * 2 + ht["table_size"] * 2) * 4
    out.append(f"kernels.hash_join,D{Dd}xN{Np_}xp{mp},"
               f"{ms:.1f},{flops:.2e},{byts:.2e},{flops/byts:.1f},memory")

    # radix groupby: dense-id grouped reduce (replaces sort+segment_sum)
    Nr2, Cc2, Gg2 = 1 << 20, 2, 4096
    ids = jnp.asarray(rng.integers(0, Gg2, Nr2).astype(np.int32))
    vals2 = jnp.asarray(rng.normal(size=(Nr2, Cc2)), jnp.float32)
    ms = _time(lambda i, v: radix_groupby(i, v, Gg2, impl="reference"),
               ids, vals2)
    parts = -(-Gg2 // 256)
    flops = 2.0 * Nr2 * 256 * (Cc2 + 1) * parts    # per-partition one-hot
    byts = (parts * Nr2 * (Cc2 + 2) + Gg2 * (Cc2 + 1)) * 4
    out.append(f"kernels.radix_groupby,N{Nr2}xC{Cc2}xG{Gg2},"
               f"{ms:.1f},{flops:.2e},{byts:.2e},{flops/byts:.0f},"
               f"{'compute' if flops/byts > RIDGE else 'memory'}")
    return out


def smoke(data=None):
    """CI part: Pallas kernel-body (interpret) vs pure-jnp reference equality
    for the data kernels, then the intensity CSV written to
    ``KERNELS_<tag>.csv`` (uploaded with the BENCH json artifacts)."""
    rng = np.random.default_rng(7)
    failures = 0

    # hash-join: shuffled unique keys + dup/miss probes, single + multi col
    try:
        keys = np.sort(rng.choice(5_000, size=700, replace=False)
                       ).astype(np.int64)
        ht = hash_build((keys,))
        sk = tuple(jnp.asarray(x) for x in ht["slot_keys"])
        si = jnp.asarray(ht["slot_idx"])
        probes = jnp.asarray(rng.integers(0, 6_000, 3_000).astype(np.int64))
        i_r, f_r = hash_probe(sk, si, (probes,), ht["max_probes"],
                              impl="reference")
        i_i, f_i = hash_probe(sk, si, (probes,), ht["max_probes"],
                              impl="interpret")
        assert np.array_equal(np.asarray(i_r), np.asarray(i_i))
        assert np.array_equal(np.asarray(f_r), np.asarray(f_i))
        # vs the searchsorted oracle (found rows index the leftmost match)
        pv = np.asarray(probes)
        ss = np.clip(np.searchsorted(keys, pv), 0, len(keys) - 1)
        hit = keys[ss] == pv
        assert np.array_equal(np.asarray(f_r), hit)
        assert np.array_equal(np.asarray(i_r)[hit], ss[hit])
        print(f"smoke.kernels.hash_join,ok,probes={len(pv)},"
              f"hits={int(hit.sum())},max_probes={ht['max_probes']}")
    except Exception:
        import traceback
        traceback.print_exc()
        failures += 1
        print("smoke.kernels.hash_join,FAIL")

    # radix groupby: interpret vs reference, padding rows included
    try:
        ids = rng.integers(-1, 600, size=20_000).astype(np.int32)
        vals = rng.normal(size=(20_000, 3)).astype(np.float32)
        s_r, c_r = radix_groupby(jnp.asarray(ids), jnp.asarray(vals), 600,
                                 impl="reference")
        s_i, c_i = radix_groupby(jnp.asarray(ids), jnp.asarray(vals), 600,
                                 impl="interpret")
        np.testing.assert_allclose(np.asarray(s_r), np.asarray(s_i),
                                   rtol=1e-5, atol=1e-5)
        assert np.array_equal(np.asarray(c_r), np.asarray(c_i))
        print(f"smoke.kernels.radix_groupby,ok,groups=600,"
              f"rows={int(np.asarray(c_r).sum())}")
    except Exception:
        import traceback
        traceback.print_exc()
        failures += 1
        print("smoke.kernels.radix_groupby,FAIL")

    # segment sum: interpret vs reference (regression guard for the shared
    # one-hot matmul pattern all three reduce kernels use)
    try:
        seg = jnp.asarray(rng.integers(0, 64, 8_192).astype(np.int32))
        v = jnp.asarray(rng.normal(size=(8_192, 2)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(segment_sum(seg, v, 64, impl="interpret")),
            np.asarray(segment_sum(seg, v, 64, impl="reference")),
            rtol=1e-5, atol=1e-5)
        print("smoke.kernels.segment_sum,ok")
    except Exception:
        import traceback
        traceback.print_exc()
        failures += 1
        print("smoke.kernels.segment_sum,FAIL")

    # the intensity CSV artifact (small shapes run fine on CPU)
    try:
        tag = os.environ.get("BENCH_TAG", "").strip() or "local"
        path = f"KERNELS_{tag}.csv"
        with open(path, "w") as f:
            f.write("\n".join(run()) + "\n")
        print(f"# wrote {path}")
    except Exception:
        import traceback
        traceback.print_exc()
        failures += 1
        print("smoke.kernels.csv,FAIL")
    return failures


if __name__ == "__main__":
    print("\n".join(run()))
