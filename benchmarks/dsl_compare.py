"""Lambda-built vs DSL-built SSB Q4.1 — the declarative-API perf A/B.

Both styles run the streaming engine at ``optimize_level=2`` with segment
fusion on.  The lambda path hand-declares its ``reads=`` lists; the DSL path
derives them from the expression AST.  On the jax backend the DSL predicates
trace straight into the fused segment kernel, so the scoped CacheStats
snapshot must show host<->device transfer counts no worse than the lambda
baseline (the PR-4 fused path) — and strictly fewer whenever a lambda flow
under-declares its reads (whole-cache upload fallback).

Emits CSV:
  dsl.flow,backend,style,wall_s,dispatch_calls,h2d_n,d2h_n,h2d_MB
  dsl.flow.verdict,backend,dsl_vs_lambda,<identical|FAIL>

The ``--smoke dsl`` part ENFORCES: byte-identical sinks, DSL transfer
counts <= the lambda fused baseline, and zero optimizer refusals
attributable to undeclared read sets on the DSL flow.
"""
from __future__ import annotations

import numpy as np

from repro.core import OptimizeOptions, StreamingEngine, available_backends
from repro.etl.queries import build_q4

from .common import BENCH_REPEATS, BENCH_ROWS, ssb_data

BACKENDS = ("numpy", "jax")
NUM_SPLITS = 8
CALIBRATION_ROWS = 65_536


def _run(data, backend, use_dsl: bool, num_splits: int = NUM_SPLITS,
         calibration_rows: int = CALIBRATION_ROWS):
    qf = build_q4(data, use_dsl=use_dsl)
    run = StreamingEngine(qf.flow, OptimizeOptions(
        num_splits=num_splits, backend=backend, optimize_level=2,
        calibration_rows=calibration_rows, fuse_segments=True)).run()
    return run, qf.sink.result()


def _assert_identical(a, b, label: str) -> None:
    assert set(a) == set(b), f"{label}: column sets differ"
    for k in b:
        assert a[k].dtype == b[k].dtype, f"{label}: dtype of {k}"
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{label} col {k}")


def run(rows: int = None) -> list:
    rows = rows or max(200_000, BENCH_ROWS // 4)
    data = ssb_data(rows)
    out = ["dsl.flow,backend,style,wall_s,dispatch_calls,h2d_n,d2h_n,h2d_MB"]
    for backend in [b for b in BACKENDS if b in available_backends()]:
        best, results = {}, {}
        for use_dsl, style in ((False, "lambda"), (True, "dsl")):
            for _ in range(max(1, BENCH_REPEATS)):
                r, res = _run(data, backend, use_dsl)
                if style not in best or r.wall_time < best[style].wall_time:
                    best[style], results[style] = r, res
            r = best[style]
            out.append(f"dsl.Q4.1,{backend},{style},{r.wall_time:.4f},"
                       f"{r.dispatch_calls},{r.h2d_transfers},"
                       f"{r.d2h_transfers},{r.h2d_bytes/1e6:.1f}")
        _assert_identical(results["dsl"], results["lambda"],
                          f"Q4.1/{backend}")
        out.append(f"dsl.Q4.1.verdict,{backend},dsl_vs_lambda,identical")
    return out


def smoke(data) -> int:
    """CI part: DSL-vs-lambda byte equality on fused adaptive Q4.1 under the
    active backend, with the declarative path's gates ENFORCED — transfer
    counts <= the lambda fused baseline (jax) and zero undeclared-read
    optimizer refusals on the DSL flow."""
    import traceback

    from repro.core import get_default_backend
    backend_name = get_default_backend().name
    try:
        r_l, lam = _run(data, backend=None, use_dsl=False,
                        num_splits=4, calibration_rows=8_192)
        r_d, dsl = _run(data, backend=None, use_dsl=True,
                        num_splits=4, calibration_rows=8_192)
        _assert_identical(dsl, lam, "Q4.1")
        undeclared = [r for r in r_d.refusals if "undeclared" in r["detail"]]
        assert not undeclared, \
            f"undeclared-read refusals on the DSL flow: {undeclared}"
        if backend_name == "jax":
            assert r_d.h2d_transfers <= r_l.h2d_transfers, \
                (f"DSL h2d transfers {r_d.h2d_transfers} > lambda fused "
                 f"baseline {r_l.h2d_transfers}")
            assert r_d.d2h_transfers <= r_l.d2h_transfers, \
                (f"DSL d2h transfers {r_d.d2h_transfers} > lambda fused "
                 f"baseline {r_l.d2h_transfers}")
    except Exception:
        traceback.print_exc()
        print("smoke.dsl.Q4.1,FAIL")
        return 1
    print(f"smoke.dsl.Q4.1,rows_ok,"
          f"h2d_n={r_l.h2d_transfers}->{r_d.h2d_transfers},"
          f"d2h_n={r_l.d2h_transfers}->{r_d.d2h_transfers},"
          f"dispatch={r_l.dispatch_calls}->{r_d.dispatch_calls},"
          f"refusals={len(r_d.refusals)}")
    return 0


if __name__ == "__main__":
    print("\n".join(run()))
