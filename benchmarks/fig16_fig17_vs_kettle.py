"""Figures 16/17 — Opt. frm. vs the Kettle-like engine on Q1-Q4 (8 GB in the
paper; BENCH_ROWS here).

Fig 16: sequential execution, inside-component MT enabled (8 threads both).
Fig 17: pipeline parallelization (ours native; Kettle-like splits the flow
horizontally — we give it the same chunked row-queues, its natural analog).

Real 1-core wall-clock: the shared-caching advantage (copy removal) is
visible even single-core; parallel gaps are reported by the simulator in
fig12/fig15.

Emits CSV: figure,query,engine,wall_s,copies
"""
from __future__ import annotations

from .common import (BENCH_REPEATS, run_kettle, run_optimized, ssb_data)

QUERIES = ("Q1.1", "Q2.1", "Q3.1", "Q4.1")
MT = {"lookup_customer": 8, "lookup_supplier": 8, "lookup_part": 8,
      "lookup_date": 8, "filter": 8, "filter_unmatched": 8}


def _best(fn):
    best = None
    for _ in range(BENCH_REPEATS):
        r, _ = fn()
        best = r if best is None or r.wall_time < best.wall_time else best
    return best


def run() -> list:
    data = ssb_data()
    out = ["fig1617.figure,query,engine,wall_s,copies"]
    for q in QUERIES:
        mt = {k: v for k, v in MT.items()}
        # Fig 16: sequential + MT
        r_opt = _best(lambda: run_optimized(q, data, num_splits=8,
                                            pipelined=False,
                                            concurrent_trees=False,
                                            mt_threads=mt))
        r_ket = _best(lambda: run_kettle(q, data, mt_threads=mt))
        out.append(f"fig16,{q},opt_frm,{r_opt.wall_time:.3f},{r_opt.copies}")
        out.append(f"fig16,{q},kettle,{r_ket.wall_time:.3f},{r_ket.copies}")
        # Fig 17: pipelined
        r_opt_p = _best(lambda: run_optimized(q, data, num_splits=8))
        r_ket_p = _best(lambda: run_kettle(q, data))
        out.append(f"fig17,{q},opt_frm_pipelined,{r_opt_p.wall_time:.3f},"
                   f"{r_opt_p.copies}")
        out.append(f"fig17,{q},kettle_split8,{r_ket_p.wall_time:.3f},"
                   f"{r_ket_p.copies}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
