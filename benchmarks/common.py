"""Shared benchmark utilities.

Scale knobs come from env so CI/smoke runs stay fast:
  BENCH_ROWS      lineorder rows (default 2_000_000 ~ 150 MB columnar)
  BENCH_REPEATS   timing repeats (default 3, best-of)
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import (OptimizedEngine, OptimizeOptions, OrdinaryEngine,
                        StreamingEngine)
from repro.etl import BUILDERS, KettleEngine
from repro.etl.ssb import generate

BENCH_ROWS = int(os.environ.get("BENCH_ROWS", 2_000_000))
BENCH_REPEATS = int(os.environ.get("BENCH_REPEATS", 3))

_DATA_CACHE: Dict[int, object] = {}


def ssb_data(rows: int = BENCH_ROWS):
    if rows not in _DATA_CACHE:
        _DATA_CACHE[rows] = generate(lineorder_rows=rows)
    return _DATA_CACHE[rows]


def best_of(fn: Callable[[], float], repeats: int = BENCH_REPEATS) -> float:
    return min(fn() for _ in range(max(1, repeats)))


def run_ordinary(qname: str, data, chunk_rows: int = 262_144):
    qf = BUILDERS[qname](data)
    run = OrdinaryEngine(qf.flow, chunk_rows=chunk_rows).run()
    return run, qf


def run_optimized(qname: str, data, **opts):
    qf = BUILDERS[qname](data)
    run = OptimizedEngine(qf.flow, OptimizeOptions(**opts)).run()
    return run, qf


def run_streaming(qname: str, data, **opts):
    qf = BUILDERS[qname](data)
    run = StreamingEngine(qf.flow, OptimizeOptions(**opts)).run()
    return run, qf


def run_kettle(qname: str, data, chunk_rows: int = 262_144, mt_threads=None):
    qf = BUILDERS[qname](data)
    run = KettleEngine(qf.flow, chunk_rows=chunk_rows,
                       mt_threads=mt_threads).run()
    return run, qf


def activity_costs_from_sequential(qname: str, data, num_splits: int = 8):
    """Algorithm 3 line 2: run the partitioned dataflow in non-pipeline
    fashion and return per-activity busy time of the MAIN execution tree
    (the source tree carries the lookups/filter — the paper's T1)."""
    qf = BUILDERS[qname](data)
    run = OptimizedEngine(qf.flow, OptimizeOptions(
        num_splits=num_splits, pipelined=False,
        concurrent_trees=False)).run()
    t1 = run.trees[0]
    costs = {name: run.activity_times[name] for name in t1}
    return costs, run


def emit(rows: List[str]) -> None:
    for r in rows:
        print(r)
