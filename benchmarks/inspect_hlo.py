"""Dry-run 'profiler': rank collectives and memory traffic in a stored
compiled HLO artifact (the hypothesis-forming tool for §Perf iterations).

  PYTHONPATH=src python -m benchmarks.inspect_hlo <arch> <shape> [mesh] [tag]
"""
from __future__ import annotations

import os
import re
import sys

import zstandard

from repro.launch.hlo_cost import (_CALLS_RE, _BODY_RE, COLLECTIVE_KINDS,
                                   HloCostWalker, _collective_cost,
                                   _while_trip, shape_elems_bytes)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_hlo(arch: str, shape: str, mesh: str = "16x16", tag: str = ""):
    suffix = f"_{tag}" if tag else ""
    path = os.path.join(ARTIFACT_DIR, f"{arch}_{shape}_{mesh}{suffix}.hlo.zst")
    with open(path, "rb") as f:
        return zstandard.ZstdDecompressor().decompress(f.read()).decode()


def top_collectives(hlo: str, n_partitions: int = 256, top: int = 12):
    w = HloCostWalker(hlo, n_partitions)
    items = []

    def walk(name, mult, stack=()):
        comp = w.comps.get(name)
        if comp is None or name in stack:
            return
        for ins in comp.instrs:
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVE_KINDS:
                _, wire = _collective_cost(comp, ins, base, n_partitions,
                                           walker=w)
                m = re.search(r'op_name="([^"]*)"', ins.attrs)
                items.append((wire * mult, base, ins.shape[:48], mult,
                              (m.group(1) if m else "")[-78:]))
            elif op == "while":
                b = _BODY_RE.search(ins.attrs)
                if b:
                    walk(b.group(1), mult * _while_trip(w, ins),
                         stack + (name,))
            elif op == "fusion":
                m = _CALLS_RE.search(ins.attrs)
                if m:
                    walk(m.group(1), mult, stack + (name,))

    walk("__entry__", 1.0)
    items.sort(reverse=True)
    out = []
    total = sum(i[0] for i in items)
    for wire, kind, shp, mult, on in items[:top]:
        out.append(f"{wire/1e9:9.2f} GB {kind:19s} x{mult:<5g} {shp:50s} {on}")
    out.append(f"{total/1e9:9.2f} GB TOTAL wire ({len(items)} collective sites)")
    return out


def top_memory(hlo: str, n_partitions: int = 256, top: int = 12):
    w = HloCostWalker(hlo, n_partitions)
    items = []

    def walk(name, mult, stack=()):
        comp = w.comps.get(name)
        if comp is None or name in stack:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                b = _BODY_RE.search(ins.attrs)
                if b:
                    walk(b.group(1), mult * _while_trip(w, ins),
                         stack + (name,))
                continue
            b = w.instr_bytes(comp, ins)
            if b > 0:
                m = re.search(r'op_name="([^"]*)"', ins.attrs)
                items.append((b * mult, ins.opcode, ins.shape[:44], mult,
                              (m.group(1) if m else "")[-74:]))

    walk("__entry__", 1.0)
    items.sort(reverse=True)
    out = []
    total = sum(i[0] for i in items)
    for byts, op, shp, mult, on in items[:top]:
        out.append(f"{byts/1e9:9.2f} GB {op:22s} x{mult:<5g} {shp:46s} {on}")
    out.append(f"{total/1e9:9.2f} GB TOTAL hbm traffic")
    return out


if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    mesh = sys.argv[3] if len(sys.argv) > 3 else "16x16"
    tag = sys.argv[4] if len(sys.argv) > 4 else ""
    hlo = load_hlo(arch, shape, mesh, tag)
    npart = 512 if mesh == "2x16x16" else 256
    print(f"=== top collectives: {arch} x {shape} [{mesh}] ===")
    print("\n".join(top_collectives(hlo, npart)))
    print(f"=== top HBM traffic: {arch} x {shape} [{mesh}] ===")
    print("\n".join(top_memory(hlo, npart)))
