"""numpy vs jax operator backend on the multi-tree SSB flows.

Runs Q4.1 and Q4.1s through the streaming engine once per registered
backend, ENFORCING engine-vs-oracle equality for every run (group keys
exact, float aggregates within the backend's ``oracle_rtol`` — the jax
backend accumulates sums in float32 through the ``kernels/segment_sum``
Pallas op, so float64 exactness is not expected), then cross-checks the two
backends against each other.

Emits CSV:
    backend.flow,backend,wall_s,copies,h2d_MB,d2h_MB,chunk_rows
    backend.<flow>.speedup,numpy_vs_jax,<ratio>,,,

Select a backend outside this section with ``OptimizeOptions(backend=...)``
or the ``REPRO_BACKEND`` env var ("numpy" / "jax").
"""
from __future__ import annotations

import numpy as np

from repro.core import OptimizeOptions, StreamingEngine, get_backend

from .common import BENCH_ROWS, ssb_data

FLOWS = ("Q4.1", "Q4.1s")
BACKENDS = ("numpy", "jax")
NUM_SPLITS = 8


def _assert_oracle(got, expect, rtol, label):
    assert set(got.keys()) == set(expect.keys()), f"{label}: column set"
    for k in expect:
        np.testing.assert_allclose(got[k], expect[k], rtol=rtol,
                                   err_msg=f"{label} column {k}")


def run(rows: int = None) -> list:
    from repro.etl import BUILDERS

    rows = rows or max(100_000, BENCH_ROWS // 8)
    data = ssb_data(rows)
    out = ["backend.flow,backend,wall_s,copies,h2d_MB,d2h_MB,chunk_rows"]
    for flow in FLOWS:
        expect = BUILDERS[flow](data).oracle(data)
        walls, results = {}, {}
        for bname in BACKENDS:
            bk = get_backend(bname)
            best = None
            for _ in range(2):          # second run = warm jit caches
                qf = BUILDERS[flow](data)
                r = StreamingEngine(qf.flow, OptimizeOptions(
                    num_splits=NUM_SPLITS, backend=bname)).run()
                got = qf.sink.result()
                # engine-vs-oracle equality is ENFORCED for every backend
                _assert_oracle(got, expect, bk.oracle_rtol,
                               f"{flow}/{bname}")
                if best is None or r.wall_time < best.wall_time:
                    best = r
            walls[bname] = best.wall_time
            results[bname] = got
            out.append(f"backend.{flow},{bname},{best.wall_time:.4f},"
                       f"{best.copies},{best.h2d_bytes/1e6:.1f},"
                       f"{best.d2h_bytes/1e6:.1f},"
                       f"{best.runtime_plan.chunk_rows or ''}")
        # cross-backend agreement at the loosest tolerance involved
        rtol = max(get_backend(b).oracle_rtol for b in BACKENDS)
        _assert_oracle(results["jax"], results["numpy"], rtol,
                       f"{flow} jax-vs-numpy")
        out.append(f"backend.{flow}.speedup,numpy_vs_jax,"
                   f"{walls['numpy'] / max(walls['jax'], 1e-9):.3f},,,")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
