"""Figure 12 — speedup vs number of pipelines (Q4.1, fact table scaled).

Method: measure per-activity costs of the Q4.1 main execution tree with a
REAL sequential engine run (Algorithm 3 lines 1-2), then replay them through
the k-core discrete-event simulator (this container has ONE core — the
paper's 8-core parallel wall-clock cannot materialize here; DESIGN §3).
The paper reports 4.7x / 3.9x / 3.7x at m=8 for 2 / 4 / 8 GB.

Emits CSV: scale,m,speedup_sim8,Tp_model
"""
from __future__ import annotations

import numpy as np

from repro.core.partitioner import partition
from repro.core.planner import build_plan, plan_runtime
from repro.core.simulate import speedup_curve
from repro.etl import BUILDERS

from .common import BENCH_ROWS, activity_costs_from_sequential, ssb_data

DEGREES = [1, 2, 4, 6, 8, 12, 16, 24, 32]
CORES = 8
SWITCH_COST = 0.004          # per excess thread, calibrated to Fig-12 decline


def run(rows_scales=(0.5, 1.0, 2.0)) -> list:
    out = ["fig12.scale,m,speedup_sim8,Tp_model_speedup"]
    for scale in rows_scales:
        rows = int(BENCH_ROWS * scale)
        data = ssb_data(rows)
        costs, _ = activity_costs_from_sequential("Q4.1", data)
        per_act = list(costs.values())
        t0 = 0.002
        plan = build_plan(costs, misc_total=t0 * len(costs),
                          sample_rows=rows, full_rows=rows, m_prime=8)
        curve = speedup_curve(per_act, rows, DEGREES, cores=CORES, t0=t0,
                              switch_cost=SWITCH_COST)
        for m in DEGREES:
            out.append(f"fig12.{scale},{m},{curve[m]:.3f},"
                       f"{plan.predict_speedup(m):.3f}")
        m_best = max(curve, key=curve.get)
        out.append(f"fig12.{scale}.best,m={m_best},"
                   f"{curve[m_best]:.3f},paper=4.7x@m8")
        # runtime plan the streaming executor would use at the model optimum
        qf = BUILDERS["Q4.1"](data)
        g_tau = partition(qf.flow)
        rt = plan_runtime(qf.flow, g_tau, num_splits=m_best, m_prime=m_best)
        depths = ";".join(f"{a}->{b}:{d}"
                          for (a, b), d in sorted(rt.channel_depth.items()))
        out.append(f"fig12.{scale}.runtime_plan,pool_width={rt.pool_width},"
                   f"channels={depths},")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
