"""Resident serving: sustained micro-batch throughput + tick-latency tails.

The section opens one ``Session.serve`` loop per backend over an SSB-shaped
flow (customer lookup -> filter -> derived profit -> terminal aggregate),
feeds the fact table through it in fixed-size micro-batch ticks, and reports
sustained rows/s plus the p50/p99 tick latency — the serving-path BENCH
numbers (latency distribution, not a wall-time race).

Emits CSV:
  serving.ssb,backend,ticks,rows_per_s,tick_p50_ms,tick_p99_ms,cold_ms
  serving.ssb.counters,backend,cold_compiles,cold_dim_h2d,warm_compiles,warm_dim_h2d

The ``--smoke serving`` part ENFORCES the resident-state contract on the
active backend: after the cold first tick, every warm tick must record ZERO
segment-kernel recompiles and ZERO dimension-table h2d re-uploads
(``CacheStats.segment_compiles`` / ``dim_h2d_transfers``), and replaying the
emitted deltas must be byte-identical to the one-shot streaming batch run.
Returns ``(failures, extras)``; extras carries the cold/warm counters for
``bench_diff`` to lock in, plus the latency tails.
"""
from __future__ import annotations

import time

import numpy as np

import repro
from repro.core import available_backends

from .common import BENCH_REPEATS, BENCH_ROWS, ssb_data

BACKENDS = ("numpy", "jax")
TICKS = 16


def _percentile(walls, q: float) -> float:
    if not walls:
        return 0.0
    return float(np.percentile(np.asarray(walls, dtype=np.float64), q))


def _build_flow(data, name: str = "serve-ssb"):
    """Serving flow over the lineorder schema: customer-nation lookup,
    region filter, derived profit, terminal group-by aggregate."""
    cust = (data.customer["c_custkey"],
            {"c_nation": data.customer["c_nation"],
             "c_region": data.customer["c_region"]})
    empty = {c: a[:0] for c, a in data.lineorder.items()}
    return (repro.flow(name)
            .source(empty)
            .lookup(cust, "lo_custkey", {"c_nation": "c_nation",
                                         "c_region": "c_region"})
            .filter(repro.col("c_region") < 3)
            # profit in units of 10k: keeps every per-group float32 partial
            # sum exactly representable (< 2^24), so incremental tick merges
            # stay byte-identical to the one-shot batch reduction
            .derive("profit",
                    (repro.col("lo_revenue") - repro.col("lo_supplycost"))
                    // 10_000)
            .aggregate(["c_nation"], {"profit": ("profit", "sum"),
                                      "avg_profit": ("profit", "avg"),
                                      "orders": ("profit", "count")})
            .sink())


def _batch_flow(data, name: str = "serve-ssb-batch"):
    f = _build_flow(data, name)
    src = next(c for c in f.flow.vertices.values()
               if type(c).__name__ == "ArraySource")
    src.set_data(data.lineorder)
    return f


def _tick_batches(lineorder, ticks: int = TICKS):
    n = len(next(iter(lineorder.values())))
    splits = np.array_split(np.arange(n), ticks)
    return [{c: a[idx] for c, a in lineorder.items()} for idx in splits]


def _serve_loop(data, backend, ticks: int = TICKS):
    """Run one full serve loop; returns (tick_results, summary)."""
    session = repro.Session(backend=backend, metadata=None)
    results = []
    with session.serve(_build_flow(data)) as srv:
        for t, batch in enumerate(_tick_batches(data.lineorder, ticks)):
            results.append(srv.tick(batch, watermark=time.time()))
        srv.close()
    return results


def run(rows: int = None) -> list:
    rows = rows or max(200_000, BENCH_ROWS // 4)
    data = ssb_data(rows)
    out = ["serving.ssb,backend,ticks,rows_per_s,tick_p50_ms,tick_p99_ms,"
           "cold_ms"]
    backends = [b for b in BACKENDS if b in available_backends()]
    for backend in backends:
        best = None
        for _ in range(max(1, BENCH_REPEATS)):
            results = _serve_loop(data, backend)
            warm = results[1:] or results
            total_rows = sum(r.rows_in for r in warm)
            total_wall = sum(r.wall_s for r in warm)
            rps = total_rows / max(total_wall, 1e-9)
            if best is None or rps > best[0]:
                best = (rps, results)
        rps, results = best
        warm_walls = [r.wall_s for r in results[1:]]
        out.append(
            f"serving.ssb,{backend},{len(results)},{rps:.0f},"
            f"{_percentile(warm_walls, 50) * 1e3:.2f},"
            f"{_percentile(warm_walls, 99) * 1e3:.2f},"
            f"{results[0].wall_s * 1e3:.2f}")
        cold, warm = results[0].cache_stats, results[1:]
        out.append(
            f"serving.ssb.counters,{backend},"
            f"{cold.get('segment_compiles', 0)},"
            f"{cold.get('dim_h2d_transfers', 0)},"
            f"{sum(r.cache_stats.get('segment_compiles', 0) for r in warm)},"
            f"{sum(r.cache_stats.get('dim_h2d_transfers', 0) for r in warm)}")
    return out


def smoke(data):
    """CI part: the resident-state contract on the active backend — warm
    ticks perform zero segment recompiles and zero dim-table h2d re-uploads,
    and the concatenated deltas replay byte-identically to the one-shot
    streaming batch run.  Returns ``(failures, extras)``."""
    import traceback

    failures = 0
    extras = {}
    try:
        results = _serve_loop(data, backend=None, ticks=8)
        cold, warm = results[0], results[1:]
        assert warm, "serving smoke needs at least two ticks"
        warm_compiles = sum(r.cache_stats.get("segment_compiles", 0)
                            for r in warm)
        warm_dim_h2d = sum(r.cache_stats.get("dim_h2d_transfers", 0)
                           for r in warm)
        assert warm_compiles == 0, \
            (f"warm ticks recompiled {warm_compiles} segment kernels — "
             f"resident serving must keep compiled segments hot")
        assert warm_dim_h2d == 0, \
            (f"warm ticks re-uploaded {warm_dim_h2d} dim tables — "
             f"resident serving must keep device dim caches hot")

        # replayed deltas == one-shot batch run, byte for byte
        fb = _batch_flow(data)
        ref = repro.Session(metadata=None).run(fb, engine="streaming").table
        rep = repro.replay_deltas(results, group_by=["c_nation"])
        assert set(rep) == set(ref), \
            f"column sets differ: {sorted(rep)} vs {sorted(ref)}"
        for k in ref:
            assert rep[k].dtype == ref[k].dtype, \
                f"column {k}: dtype {rep[k].dtype} != batch {ref[k].dtype}"
            assert rep[k].tobytes() == ref[k].tobytes(), \
                f"column {k}: replayed deltas differ from the batch run"

        warm_walls = [r.wall_s for r in warm]
        extras = {
            "counters": {
                "ticks": len(results),
                "cold_segment_compiles":
                    cold.cache_stats.get("segment_compiles", 0),
                "cold_dim_h2d_transfers":
                    cold.cache_stats.get("dim_h2d_transfers", 0),
                "warm_segment_compiles": warm_compiles,
                "warm_dim_h2d_transfers": warm_dim_h2d,
            },
            "rows_per_s": round(sum(r.rows_in for r in warm)
                                / max(sum(warm_walls), 1e-9), 1),
            "tick_p50_ms": round(_percentile(warm_walls, 50) * 1e3, 3),
            "tick_p99_ms": round(_percentile(warm_walls, 99) * 1e3, 3),
        }
        print(f"smoke.serving,ok,ticks={len(results)},"
              f"cold_compiles={extras['counters']['cold_segment_compiles']},"
              f"warm_compiles=0,warm_dim_h2d=0,"
              f"p99_ms={extras['tick_p99_ms']}")
    except Exception:
        traceback.print_exc()
        failures += 1
        print("smoke.serving,FAIL")
    return failures, extras


if __name__ == "__main__":
    print("\n".join(run()))
