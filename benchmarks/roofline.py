"""§Roofline generator — reads the dry-run artifacts and prints the
per-(arch x shape x mesh) three-term roofline table.

Emits CSV:
  arch,shape,mesh,t_compute_ms,t_memory_ms,t_collective_ms,bottleneck,
  useful_flops_fraction,roofline_fraction,peak_gib,tpu_corrected_peak_gib
"""
from __future__ import annotations

import glob
import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load(mesh: str = None, tag: str = ""):
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec["mesh"] != mesh:
            continue
        if rec.get("tag", "") != tag:
            continue
        recs.append(rec)
    return recs


def run(mesh: str = "16x16", tag: str = "") -> list:
    out = ["roofline.arch,shape,mesh,t_compute_ms,t_memory_ms,"
           "t_collective_ms,bottleneck,useful_flops_frac,roofline_frac,"
           "peak_gib,tpu_corrected_peak_gib"]
    for rec in load(mesh, tag):
        r = rec["roofline"]
        m = rec["memory"]
        out.append(
            f"roofline.{rec['arch']},{rec['shape']},{rec['mesh']},"
            f"{r['t_compute_s']*1e3:.2f},{r['t_memory_s']*1e3:.2f},"
            f"{r['t_collective_s']*1e3:.2f},{r['bottleneck']},"
            f"{r['useful_flops_fraction']:.3f},"
            f"{r['roofline_fraction']:.3f},"
            f"{m['peak_bytes_per_device']/2**30:.2f},"
            f"{m.get('tpu_corrected_peak_bytes', m['peak_bytes_per_device'])/2**30:.2f}")
    if len(out) == 1:
        out.append("roofline.NO_ARTIFACTS_RUN_DRYRUN_FIRST,,,,,,,,,,")
    return out


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print("\n".join(run(mesh)))
