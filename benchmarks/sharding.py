"""Sharded execution benchmark + CI smoke part.

    PYTHONPATH=src python -m benchmarks.sharding          # shards sweep
    PYTHONPATH=src python -m benchmarks.run --smoke sharding

Sweeps Q4.1 over shard counts on the configured route (``REPRO_SHARD_IMPL``
— the CI sharding leg pins ``process``) and reports rows/s per shard count.
The smoke part enforces:

  * byte-identity: every sharded run's sink table equals the serial run's,
    column for column, dtype for dtype, row for row;
  * the merge phase actually ran (a ``shard-merge`` span plus one
    ``shard-k`` span per shard in the trace);
  * scatter, not broadcast: no worker is ever shipped the full source
    table (``scatter_bytes`` strictly below ``source_bytes``), and the
    shuffle volume (stashed partials) stays below the source volume.

Per-shard-count throughput and row layout go into the bench JSON under the
section's ``shards`` field — bench_diff gates only ``status`` /
``cache_stats`` / ``counters``, so these timing-dependent extras ride along
ungated, tracking the trajectory without flaking CI.
"""
from __future__ import annotations

import time
import traceback

import numpy as np

SHARD_COUNTS = (1, 2, 4)


def _serial(qname, data, num_splits=4):
    from repro.core import OptimizeOptions, StreamingEngine
    from repro.etl import BUILDERS
    qf = BUILDERS[qname](data)
    run = StreamingEngine(qf.flow,
                          OptimizeOptions(num_splits=num_splits,
                                          shards=1)).run()
    return run, qf.sink.result()


def _sharded(qname, data, shards, num_splits=4, tracer=None):
    from repro.core import OptimizeOptions, StreamingEngine
    from repro.etl import BUILDERS
    from repro.obs import trace as obs_trace
    qf = BUILDERS[qname](data)
    scope = (obs_trace.trace_scope(tracer) if tracer is not None
             else _null())
    with scope:
        run = StreamingEngine(qf.flow, OptimizeOptions(
            num_splits=num_splits, shards=shards)).run()
    return run, qf.sink.result()


def _null():
    from contextlib import nullcontext
    return nullcontext()


def _assert_identical(got, want, label):
    assert set(got) == set(want), f"{label}: column sets differ"
    for k in want:
        assert got[k].dtype == want[k].dtype, f"{label}: dtype of {k}"
        np.testing.assert_array_equal(got[k], want[k],
                                      err_msg=f"{label}: column {k}")


def _shard_result(qname, data, shards, num_splits=4):
    """Run the ShardRunner directly to surface the ShardResult the engine
    folds away — the scatter/shuffle byte accounting under test."""
    from repro.core import (OptimizeOptions, partition, plan_runtime,
                            plan_shards, resolve_backend)
    from repro.core.engine import _assign_backend
    from repro.core.shard import ShardRunner
    from repro.etl import BUILDERS
    qf = BUILDERS[qname](data)
    opts = OptimizeOptions(num_splits=num_splits, shards=shards)
    bk = resolve_backend(opts.backend)
    _assign_backend(qf.flow, bk)
    g_tau = partition(qf.flow)
    rplan = plan_runtime(qf.flow, g_tau, num_splits=num_splits,
                         m_prime=num_splits, backend=bk)
    plan = plan_shards(qf.flow, g_tau, shards, "inline", opts, bk)
    assert plan is not None, f"{qname}: plan_shards degraded to serial"
    res = ShardRunner(qf.flow, g_tau, opts, rplan, plan).execute()
    return res, qf.sink.result()


# ---------------------------------------------------------------------------
#  CI smoke part
# ---------------------------------------------------------------------------
def smoke(data):
    """CI part: sharded Q4.1 byte-identity at shards in {1,2,4} on the
    configured route, merge-span presence, and the no-broadcast guarantee.
    Returns ``(failures, extras)``; extras carries per-shard-count rows/s
    (the gate-ignored ``shards`` field of the bench record)."""
    from repro.obs import trace as obs_trace

    failures = 0
    extras = {"shards": {}}
    rows = len(next(iter(data.lineorder.values())))
    try:
        _, baseline = _serial("Q4.1", data)
    except Exception:
        traceback.print_exc()
        print("smoke.sharding,serial,FAIL")
        return 1, extras

    for s in SHARD_COUNTS:
        tracer = obs_trace.Tracer(name=f"sharding-{s}", measuring=False)
        t0 = time.time()
        try:
            run, got = _sharded("Q4.1", data, s, tracer=tracer)
            wall = time.time() - t0
            _assert_identical(got, baseline, f"Q4.1 shards={s}")
            assert run.shards == s, \
                f"shards={s}: run degraded to {run.shards}"
            names = [e.get("name") for e in tracer.events]
            if s > 1:
                assert sum(run.shard_rows) == rows, \
                    f"shards={s}: shard_rows {run.shard_rows} != {rows}"
                assert "shard-merge" in names, \
                    f"shards={s}: no shard-merge span in trace"
                for k in range(s):
                    assert f"shard-{k}" in names, \
                        f"shards={s}: no shard-{k} span in trace"
        except Exception:
            traceback.print_exc()
            failures += 1
            print(f"smoke.sharding,shards={s},FAIL")
            continue
        extras["shards"][str(s)] = {
            "wall_s": round(wall, 4),
            "rows_per_s": round(rows / wall) if wall > 0 else None,
            "shard_rows": list(run.shard_rows),
        }
        print(f"smoke.sharding,shards={s},rows_ok,"
              f"rows_per_s={extras['shards'][str(s)]['rows_per_s']}")

    # scatter-not-broadcast: each worker receives only its partition and
    # the coordinator receives partials, never the full table
    try:
        res, got = _shard_result("Q4.1", data, 2)
        _assert_identical(got, baseline, "Q4.1 runner shards=2")
        assert res.scatter_bytes < res.source_bytes, \
            (f"full-table broadcast: scatter {res.scatter_bytes} !< "
             f"source {res.source_bytes}")
        assert res.shuffle_bytes < res.source_bytes, \
            (f"shuffle {res.shuffle_bytes} !< source {res.source_bytes}")
        extras["shards"]["scatter_bytes"] = res.scatter_bytes
        extras["shards"]["source_bytes"] = res.source_bytes
        extras["shards"]["shuffle_bytes"] = res.shuffle_bytes
        print(f"smoke.sharding,scatter_ok,scatter={res.scatter_bytes},"
              f"source={res.source_bytes},shuffle={res.shuffle_bytes}")
    except Exception:
        traceback.print_exc()
        failures += 1
        print("smoke.sharding,scatter,FAIL")
    return failures, extras


# ---------------------------------------------------------------------------
#  Full bench: shards sweep at BENCH_ROWS
# ---------------------------------------------------------------------------
def run() -> list:
    from .common import BENCH_REPEATS, emit, ssb_data

    data = ssb_data()
    rows = len(next(iter(data.lineorder.values())))
    out = ["# sharding: Q4.1 rows/s by shard count "
           "(route per REPRO_SHARD_IMPL)",
           "query,shards,wall_s,rows_per_s"]
    _, baseline = _serial("Q4.1", data)
    for s in SHARD_COUNTS + (8,):
        best = None
        for _ in range(BENCH_REPEATS):
            t0 = time.time()
            run_, got = _sharded("Q4.1", data, s)
            wall = time.time() - t0
            _assert_identical(got, baseline, f"Q4.1 shards={s}")
            best = wall if best is None else min(best, wall)
        out.append(f"Q4.1,{s},{best:.4f},{rows / best:.0f}")
    emit(out)
    return out


if __name__ == "__main__":
    raise SystemExit(0 if isinstance(run(), list) else 1)
