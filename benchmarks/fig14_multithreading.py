"""Figure 14 — inside-component multithreading speedup (supplier lookup made
the bottleneck; threads 1..16; cores 2/4/6/8).

The real mt path is validated for equality in tests; the speedup CURVE is
simulated from the measured bottleneck/other split (1-core container).

Emits CSV: cores,threads,speedup
"""
from __future__ import annotations

from repro.core.simulate import multithreading_curve

from .common import activity_costs_from_sequential, ssb_data

THREADS = [1, 2, 4, 8, 12, 16]


def run() -> list:
    data = ssb_data()
    costs, _ = activity_costs_from_sequential("Q4.1", data)
    bottleneck = costs.get("lookup_supplier", 0.0)
    other = sum(costs.values()) - bottleneck
    out = ["fig14.cores,threads,speedup"]
    for cores in (2, 4, 6, 8):
        curve = multithreading_curve(bottleneck, other, THREADS,
                                     cores=cores, parallel_fraction=0.95,
                                     switch_cost=0.02)
        for t in THREADS:
            out.append(f"fig14.{cores},{t},{curve[t]:.3f}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
