"""Benchmark aggregator — one section per paper table/figure + the roofline
table.  Prints CSV lines (name,...).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig12 roofline
Scale via env: BENCH_ROWS (default 2,000,000), BENCH_REPEATS.
"""
from __future__ import annotations

import sys
import time
import traceback

from . import (fig12_pipeline_speedup, fig13_cpu_usage,
               fig14_multithreading, fig15_optimization,
               fig16_fig17_vs_kettle, kernel_bench, roofline,
               theorem1_accuracy)

SECTIONS = {
    "fig12": fig12_pipeline_speedup.run,
    "fig13": fig13_cpu_usage.run,
    "fig14": fig14_multithreading.run,
    "fig15": fig15_optimization.run,
    "fig1617": fig16_fig17_vs_kettle.run,
    "theorem1": theorem1_accuracy.run,
    "kernels": kernel_bench.run,
    "roofline": lambda: roofline.run("16x16") + roofline.run("2x16x16"),
}


def main() -> int:
    names = [a for a in sys.argv[1:] if a in SECTIONS] or list(SECTIONS)
    failures = []
    for name in names:
        print(f"# === {name} ===")
        t0 = time.time()
        try:
            for line in SECTIONS[name]():
                print(line)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        print("# FAILED sections:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
