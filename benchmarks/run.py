"""Benchmark aggregator — one section per paper table/figure + the roofline
table and the streaming/optimizer/fusion comparisons.  Prints CSV lines
(name,...).

  PYTHONPATH=src python -m benchmarks.run            # all sections
  PYTHONPATH=src python -m benchmarks.run fig12 roofline streaming
  PYTHONPATH=src python -m benchmarks.run --smoke    # fast CI equivalence guard
  PYTHONPATH=src python -m benchmarks.run --smoke fusion optimizer   # parts

Scale via env: BENCH_ROWS (default 2,000,000), BENCH_REPEATS.

Every invocation also writes a machine-readable ``BENCH_<tag>.json`` next to
the working directory (tag from ``BENCH_TAG``, default "local"): per-section
wall time and status, the per-section ``CacheStats`` snapshot (copies,
h2d/d2h transfers, arena hits/misses/bytes-reused — collected with a scoped
``cache_stats_scope`` so concurrent noise never leaks in), and the active
backend — the cross-PR perf trajectory record.  Schema in
``benchmarks/README.md``.

``--smoke`` runs the ordinary / optimized / streaming engines on tiny
multi-tree SSB dataflows and asserts (1) identical sink rows, in order,
across all three paths and (2) the shared-caching engines record fewer
copies than the ordinary engine — a cheap guard for engine refactors.  It
then repeats Q4.1/Q4.1s under BOTH operator backends (numpy and jax),
enforcing engine-vs-oracle equality per backend and numpy-vs-jax agreement
— the accelerated path's refactor guard.  Select a backend for the
engine runs themselves with ``REPRO_BACKEND=jax``.  The optimizer part
re-runs Q4.1/Q4.1s with ``optimize_level=2`` (cost-based rewriting) and
enforces byte equality against the static plans; the fusion part re-runs
them with segment fusion + the CacheArena on and enforces byte equality
plus REDUCED backend dispatch / h2d transfer counts.  Pass part names after
``--smoke`` (engines, backend, optimizer, fusion) to run a subset.
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback

from . import (backend_compare, dsl_compare, fig12_pipeline_speedup,
               fig13_cpu_usage, fig14_multithreading, fig15_optimization,
               fig16_fig17_vs_kettle, fusion, kernel_bench, optimizer,
               roofline, serving, sharding, streaming, theorem1_accuracy)

SECTIONS = {
    "fig12": fig12_pipeline_speedup.run,
    "fig13": fig13_cpu_usage.run,
    "fig14": fig14_multithreading.run,
    "fig15": fig15_optimization.run,
    "fig1617": fig16_fig17_vs_kettle.run,
    "theorem1": theorem1_accuracy.run,
    "kernels": kernel_bench.run,
    "serving": serving.run,
    "streaming": streaming.run,
    "backend": backend_compare.run,
    "optimizer": optimizer.run,
    "fusion": fusion.run,
    "dsl": dsl_compare.run,
    "sharding": sharding.run,
    "roofline": lambda: roofline.run("16x16") + roofline.run("2x16x16"),
}

SMOKE_FLOWS = ("Q1.1", "Q2.1", "Q4.1", "Q4.1s")
SMOKE_PARTS = ("engines", "backend", "optimizer", "fusion", "dsl", "kernels",
               "serving", "sharding")


# ---------------------------------------------------------------------------
#  BENCH_<tag>.json — machine-readable perf trajectory
# ---------------------------------------------------------------------------
def bench_tag() -> str:
    return os.environ.get("BENCH_TAG", "").strip() or "local"


def write_bench_json(sections: dict, mode: str, path: str = None) -> str:
    """Write the per-section results dict as BENCH_<tag>.json and return the
    path.  ``sections`` maps section name -> {"wall_s", "status",
    "cache_stats", ...}; top-level metadata records the backend and scale so
    trajectories across PRs compare like with like."""
    from repro.core import config, get_default_backend
    from repro.obs import trace as obs_trace

    from .common import BENCH_REPEATS, BENCH_ROWS
    tag = bench_tag()                 # one derivation: file name == payload
    payload = {
        "tag": tag,
        "mode": mode,
        "backend": get_default_backend().name,
        # how SSB flows were built ("dsl" | "lambda") — the perf trajectory
        # must tell the declarative path apart from the legacy lambda path
        "flow_style": config.flow_style(),
        "bench_rows": BENCH_ROWS,
        "bench_repeats": BENCH_REPEATS,
        "created_unix": time.time(),
        # run identity — joins this payload to metadata-store records and
        # REPRO_TRACE artifacts from the same invocation (top-level only:
        # bench_diff gates the per-section records, not these)
        "run_id": obs_trace.new_run_id(),
        "created_iso": obs_trace.iso_now(),
        "git_sha": obs_trace.git_sha(),
        "sections": sections,
    }
    path = path or f"BENCH_{tag}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path


def _section_record(wall: float, status: str, stats) -> dict:
    return {"wall_s": round(wall, 4), "status": status,
            "cache_stats": stats.snapshot()}


# ---------------------------------------------------------------------------
#  Smoke parts
# ---------------------------------------------------------------------------
def _smoke_engines(data) -> int:
    import numpy as np

    from repro.core import (OptimizedEngine, OptimizeOptions, OrdinaryEngine,
                            StreamingEngine, get_default_backend)
    from repro.etl import BUILDERS

    # oracle tolerance follows the active backend: float64 numpy is exact to
    # 1e-9; the jax backend accumulates sums in float32 (segment_sum kernel)
    oracle_rtol = get_default_backend().oracle_rtol
    failures = 0
    for qname in SMOKE_FLOWS:
        qf = BUILDERS[qname](data)
        expect = qf.oracle(data)
        r_ord = OrdinaryEngine(qf.flow, chunk_rows=16_384).run()
        baseline = qf.sink.result()

        runs = {}
        for label, engine_cls in (("optimized", OptimizedEngine),
                                  ("streaming", StreamingEngine)):
            qf2 = BUILDERS[qname](data)
            runs[label] = engine_cls(
                qf2.flow, OptimizeOptions(num_splits=4)).run()
            got = qf2.sink.result()
            try:
                assert set(got.keys()) == set(baseline.keys()), "column set"
                for k in baseline:   # identical rows, identical ORDER
                    np.testing.assert_array_equal(
                        got[k], baseline[k],
                        err_msg=f"{qname} {label} column {k}")
                for k in expect:     # and both match the independent oracle
                    np.testing.assert_allclose(got[k], expect[k],
                                               rtol=oracle_rtol)
            except AssertionError:
                traceback.print_exc()
                failures += 1
                print(f"smoke.{qname},{label},FAIL")
                continue
            print(f"smoke.{qname},{label},rows_ok,"
                  f"copies={runs[label].copies},ord_copies={r_ord.copies}")
        for label, r in runs.items():
            if not r.copies < r_ord.copies:
                print(f"smoke.{qname},{label},FAIL,copies {r.copies} !< "
                      f"ordinary {r_ord.copies}")
                failures += 1
    return failures


def _smoke_backends(data) -> int:
    """numpy-vs-jax operator backend comparison on the multi-tree flows:
    per-backend engine-vs-oracle equality + cross-backend agreement.  The
    equality harness (flows, tolerance rules, assertions) is shared with the
    `backend` section so the two cannot drift."""
    from repro.core import (OptimizeOptions, StreamingEngine, get_backend,
                            get_default_backend)
    from repro.etl import BUILDERS

    from .backend_compare import BACKENDS, FLOWS, _assert_oracle

    if get_default_backend().name != "numpy":
        # the comparison below runs BOTH backends explicitly, so a non-numpy
        # engine leg (REPRO_BACKEND=jax in the CI matrix) would repeat the
        # numpy leg's most expensive work for no added coverage
        print("smoke.backend,skipped,covered by the numpy leg")
        return 0

    failures = 0
    for qname in FLOWS:
        expect = BUILDERS[qname](data).oracle(data)
        results = {}
        for bname in BACKENDS:
            qf = BUILDERS[qname](data)
            try:
                r = StreamingEngine(qf.flow, OptimizeOptions(
                    num_splits=4, backend=bname)).run()
                got = qf.sink.result()
                _assert_oracle(got, expect, get_backend(bname).oracle_rtol,
                               f"{qname} backend={bname}")
            except Exception:
                traceback.print_exc()
                failures += 1
                print(f"smoke.backend.{qname},{bname},FAIL")
                continue
            results[bname] = got
            print(f"smoke.backend.{qname},{bname},oracle_ok,"
                  f"wall={r.wall_time:.3f},h2d_MB={r.h2d_bytes/1e6:.1f}")
        if len(results) == len(BACKENDS):
            rtol = max(get_backend(b).oracle_rtol for b in BACKENDS)
            try:
                _assert_oracle(results["jax"], results["numpy"], rtol,
                               f"{qname} jax-vs-numpy")
            except AssertionError:
                traceback.print_exc()
                failures += 1
                print(f"smoke.backend.{qname},jax_vs_numpy,FAIL")
                continue
            print(f"smoke.backend.{qname},jax_vs_numpy,rows_agree")
    return failures


def smoke(parts=None) -> int:
    """Tiny-row engine equivalence guards; ``parts`` selects a subset of
    SMOKE_PARTS (default: all).  Writes BENCH_<tag>.json with one record per
    part."""
    from repro.core import cache_stats_scope
    from repro.etl.ssb import generate

    parts = list(parts or SMOKE_PARTS)
    unknown = [p for p in parts if p not in SMOKE_PARTS]
    if unknown:
        raise ValueError(f"unknown smoke part(s) {unknown}; "
                         f"valid: {list(SMOKE_PARTS)}")
    data = generate(lineorder_rows=50_000, customers=2_000, suppliers=200,
                    parts=1_000, seed=5)
    runners = {
        "engines": lambda: _smoke_engines(data),
        "backend": lambda: _smoke_backends(data),
        # cost-based adaptive optimizer: rewritten-vs-static byte equality
        "optimizer": lambda: optimizer.smoke(data),
        # segment fusion + arena: fused-vs-unfused byte equality + enforced
        # dispatch/h2d reductions
        "fusion": lambda: fusion.smoke(data),
        # declarative DSL vs legacy lambda flows: byte equality + transfer
        # counts <= the lambda fused baseline + zero undeclared refusals
        "dsl": lambda: dsl_compare.smoke(data),
        # data-kernel sweeps: hash-join / radix-groupby / segment-sum
        # ref-vs-interpret equality + the intensity CSV artifact
        "kernels": kernel_bench.smoke,
        # resident serving: warm ticks must record zero segment recompiles
        # and zero dim-table h2d re-uploads; replayed deltas byte-identical
        # to the one-shot batch run
        "serving": lambda: serving.smoke(data),
        # sharded execution: byte-identity at shards 1/2/4 on the
        # configured route, merge-span presence, scatter-not-broadcast
        "sharding": lambda: sharding.smoke(data),
    }
    failures = 0
    records = {}
    for part in parts:
        t0 = time.time()
        with cache_stats_scope() as stats:
            try:
                got = runners[part]()
            except Exception:
                traceback.print_exc()
                got = 1
        # runners return either a failure count or (failures, extras) where
        # extras (e.g. transfer counters) merges into the section record for
        # bench_diff to lock in
        part_failures, extras = got if isinstance(got, tuple) else (got, {})
        failures += part_failures
        record = _section_record(
            time.time() - t0, "FAIL" if part_failures else "PASS", stats)
        record.update(extras)
        records[f"smoke.{part}"] = record
    path = write_bench_json(records, mode="smoke")
    print(f"# wrote {path}")
    print(f"smoke,{'FAIL' if failures else 'PASS'},{failures} failures")
    return 1 if failures else 0


def main() -> int:
    from repro.core import cache_stats_scope

    args = sys.argv[1:]
    if "--smoke" in args:
        rest = [a for a in args if a != "--smoke"]
        unknown = [a for a in rest if a not in SMOKE_PARTS]
        if unknown:
            # a typo'd part silently falling through to the FULL smoke is
            # exactly the failure a green CI job would never surface
            print(f"unknown --smoke part(s) {unknown}; "
                  f"valid: {list(SMOKE_PARTS)}")
            return 2
        return smoke(rest or None)
    unknown = [a for a in args if a not in SECTIONS]
    if unknown:
        # same hazard in full-run mode: a typo'd section must not silently
        # fall through to running ALL sections with a green exit
        print(f"unknown section(s) {unknown}; valid: {sorted(SECTIONS)}")
        return 2
    names = args or list(SECTIONS)
    failures = []
    records = {}
    for name in names:
        print(f"# === {name} ===")
        t0 = time.time()
        with cache_stats_scope() as stats:
            try:
                for line in SECTIONS[name]():
                    print(line)
                status = "ok"
            except Exception:
                traceback.print_exc()
                failures.append(name)
                status = "fail"
        records[name] = _section_record(time.time() - t0, status, stats)
        print(f"# {name} done in {records[name]['wall_s']:.1f}s", flush=True)
    path = write_bench_json(records, mode="full")
    print(f"# wrote {path}")
    if failures:
        print("# FAILED sections:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
