"""Benchmark aggregator — one section per paper table/figure + the roofline
table and the streaming-executor comparison.  Prints CSV lines (name,...).

  PYTHONPATH=src python -m benchmarks.run            # all sections
  PYTHONPATH=src python -m benchmarks.run fig12 roofline streaming
  PYTHONPATH=src python -m benchmarks.run --smoke    # fast CI equivalence guard

Scale via env: BENCH_ROWS (default 2,000,000), BENCH_REPEATS.

``--smoke`` runs the ordinary / optimized / streaming engines on tiny
multi-tree SSB dataflows and asserts (1) identical sink rows, in order,
across all three paths and (2) the shared-caching engines record fewer
copies than the ordinary engine — a cheap guard for engine refactors.  It
then repeats Q4.1/Q4.1s under BOTH operator backends (numpy and jax),
enforcing engine-vs-oracle equality per backend and numpy-vs-jax agreement
— the accelerated path's refactor guard.  Select a backend for the
engine runs themselves with ``REPRO_BACKEND=jax``.  Finally the optimizer
part re-runs Q4.1/Q4.1s with ``optimize_level=2`` (cost-based rewriting)
and enforces byte equality against the static plans.
"""
from __future__ import annotations

import sys
import time
import traceback

from . import (backend_compare, fig12_pipeline_speedup, fig13_cpu_usage,
               fig14_multithreading, fig15_optimization,
               fig16_fig17_vs_kettle, kernel_bench, optimizer, roofline,
               streaming, theorem1_accuracy)

SECTIONS = {
    "fig12": fig12_pipeline_speedup.run,
    "fig13": fig13_cpu_usage.run,
    "fig14": fig14_multithreading.run,
    "fig15": fig15_optimization.run,
    "fig1617": fig16_fig17_vs_kettle.run,
    "theorem1": theorem1_accuracy.run,
    "kernels": kernel_bench.run,
    "streaming": streaming.run,
    "backend": backend_compare.run,
    "optimizer": optimizer.run,
    "roofline": lambda: roofline.run("16x16") + roofline.run("2x16x16"),
}

SMOKE_FLOWS = ("Q1.1", "Q2.1", "Q4.1", "Q4.1s")


def smoke() -> int:
    """Tiny-row engine equivalence: ordinary vs optimized vs streaming,
    then numpy-vs-jax operator-backend equivalence on the multi-tree flows."""
    import numpy as np

    from repro.core import (OptimizedEngine, OptimizeOptions, OrdinaryEngine,
                            StreamingEngine, get_default_backend)
    from repro.etl import BUILDERS
    from repro.etl.ssb import generate

    data = generate(lineorder_rows=50_000, customers=2_000, suppliers=200,
                    parts=1_000, seed=5)
    # oracle tolerance follows the active backend: float64 numpy is exact to
    # 1e-9; the jax backend accumulates sums in float32 (segment_sum kernel)
    oracle_rtol = get_default_backend().oracle_rtol
    failures = 0
    for qname in SMOKE_FLOWS:
        qf = BUILDERS[qname](data)
        expect = qf.oracle(data)
        r_ord = OrdinaryEngine(qf.flow, chunk_rows=16_384).run()
        baseline = qf.sink.result()

        runs = {}
        for label, engine_cls in (("optimized", OptimizedEngine),
                                  ("streaming", StreamingEngine)):
            qf2 = BUILDERS[qname](data)
            runs[label] = engine_cls(
                qf2.flow, OptimizeOptions(num_splits=4)).run()
            got = qf2.sink.result()
            try:
                assert set(got.keys()) == set(baseline.keys()), "column set"
                for k in baseline:   # identical rows, identical ORDER
                    np.testing.assert_array_equal(
                        got[k], baseline[k],
                        err_msg=f"{qname} {label} column {k}")
                for k in expect:     # and both match the independent oracle
                    np.testing.assert_allclose(got[k], expect[k],
                                               rtol=oracle_rtol)
            except AssertionError:
                traceback.print_exc()
                failures += 1
                print(f"smoke.{qname},{label},FAIL")
                continue
            print(f"smoke.{qname},{label},rows_ok,"
                  f"copies={runs[label].copies},ord_copies={r_ord.copies}")
        for label, r in runs.items():
            if not r.copies < r_ord.copies:
                print(f"smoke.{qname},{label},FAIL,copies {r.copies} !< "
                      f"ordinary {r_ord.copies}")
                failures += 1
    if get_default_backend().name == "numpy":
        failures += _smoke_backends(data)
    else:
        # the comparison below runs BOTH backends explicitly, so a non-numpy
        # engine leg (REPRO_BACKEND=jax in the CI matrix) would repeat the
        # numpy leg's most expensive work for no added coverage
        print("smoke.backend,skipped,covered by the numpy leg")
    # cost-based adaptive optimizer: rewritten-vs-static byte equality on the
    # multi-tree flows under the active backend (optimizer.smoke)
    failures += optimizer.smoke(data)
    print(f"smoke,{'FAIL' if failures else 'PASS'},{failures} failures")
    return 1 if failures else 0


def _smoke_backends(data) -> int:
    """numpy-vs-jax operator backend comparison on the multi-tree flows:
    per-backend engine-vs-oracle equality + cross-backend agreement.  The
    equality harness (flows, tolerance rules, assertions) is shared with the
    `backend` section so the two cannot drift."""
    from repro.core import OptimizeOptions, StreamingEngine, get_backend
    from repro.etl import BUILDERS

    from .backend_compare import BACKENDS, FLOWS, _assert_oracle

    failures = 0
    for qname in FLOWS:
        expect = BUILDERS[qname](data).oracle(data)
        results = {}
        for bname in BACKENDS:
            qf = BUILDERS[qname](data)
            try:
                r = StreamingEngine(qf.flow, OptimizeOptions(
                    num_splits=4, backend=bname)).run()
                got = qf.sink.result()
                _assert_oracle(got, expect, get_backend(bname).oracle_rtol,
                               f"{qname} backend={bname}")
            except Exception:
                traceback.print_exc()
                failures += 1
                print(f"smoke.backend.{qname},{bname},FAIL")
                continue
            results[bname] = got
            print(f"smoke.backend.{qname},{bname},oracle_ok,"
                  f"wall={r.wall_time:.3f},h2d_MB={r.h2d_bytes/1e6:.1f}")
        if len(results) == len(BACKENDS):
            rtol = max(get_backend(b).oracle_rtol for b in BACKENDS)
            try:
                _assert_oracle(results["jax"], results["numpy"], rtol,
                               f"{qname} jax-vs-numpy")
            except AssertionError:
                traceback.print_exc()
                failures += 1
                print(f"smoke.backend.{qname},jax_vs_numpy,FAIL")
                continue
            print(f"smoke.backend.{qname},jax_vs_numpy,rows_agree")
    return failures


def main() -> int:
    if "--smoke" in sys.argv[1:]:
        return smoke()
    names = [a for a in sys.argv[1:] if a in SECTIONS] or list(SECTIONS)
    failures = []
    for name in names:
        print(f"# === {name} ===")
        t0 = time.time()
        try:
            for line in SECTIONS[name]():
                print(line)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        print("# FAILED sections:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
