"""Theorem 1 / Algorithm 3 accuracy — does the analytically-chosen degree m*
land on the simulated optimum?

Runs Algorithm 3 (sample sequential + pipelined run, estimate t0/c/lambda),
computes m*, and compares T_p(m*) against the best simulated m on an 8-core
machine.

Emits CSV: quantity,value
"""
from __future__ import annotations

import numpy as np

from repro.core.planner import build_plan, choose_degree
from repro.core.simulate import speedup_curve

from .common import BENCH_ROWS, activity_costs_from_sequential, ssb_data


def run() -> list:
    data = ssb_data()
    rows = BENCH_ROWS
    costs, _ = activity_costs_from_sequential("Q4.1", data)
    t0 = 0.002
    plan = build_plan(costs, misc_total=t0 * len(costs), sample_rows=rows,
                      full_rows=rows, m_prime=8)
    degrees = list(range(1, 33))
    curve = speedup_curve(list(costs.values()), rows, degrees, cores=8,
                          t0=t0, switch_cost=0.004)
    m_sim = max(curve, key=curve.get)
    m_star = choose_degree(plan, cores=8)
    out = ["theorem1.quantity,value"]
    out.append(f"theorem1.m_star_raw,{plan.m_star:.1f}")
    out.append(f"theorem1.m_star_core_capped,{m_star}")
    out.append(f"theorem1.m_sim_best,{m_sim}")
    out.append(f"theorem1.speedup_at_m_star,{curve[m_star]:.3f}")
    out.append(f"theorem1.speedup_at_sim_best,{curve[m_sim]:.3f}")
    out.append(f"theorem1.regret_pct,"
               f"{(curve[m_sim]-curve[m_star])/curve[m_sim]*100:.2f}")
    out.append(f"theorem1.staggering,{plan.staggering}")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
