"""Generate the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
the dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.experiments_tables > /tmp/tables.md
"""
from __future__ import annotations

from .roofline import load


def dryrun_table(mesh: str) -> list:
    out = [f"### Mesh {mesh}",
           "",
           "| arch | shape | kind | compile s | args GiB | peak GiB (CPU-reported) | peak GiB (TPU-corrected lower bound) | collectives (count by kind) |",
           "|---|---|---|---|---|---|---|---|"]
    for rec in load(mesh):
        m = rec["memory"]
        r = rec["roofline"]
        cc = r.get("collective_count_by_kind", {})
        ccs = " ".join(f"{k.split('-')[-1][:4] if '-' in k else k[:4]}:"
                       f"{int(v)}" for k, v in sorted(cc.items()))
        out.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['kind']} "
            f"| {rec['compile_s']:.0f} "
            f"| {m['argument_bytes']/2**30:.2f} "
            f"| {m['peak_bytes_per_device']/2**30:.2f} "
            f"| {m.get('tpu_corrected_peak_bytes', 0)/2**30:.2f} "
            f"| {ccs} |")
    return out


def roofline_table(mesh: str = "16x16") -> list:
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bottleneck "
           "| useful FLOPs frac | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for rec in load(mesh):
        r = rec["roofline"]
        out.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {r['t_compute_s']*1e3:.1f} | {r['t_memory_s']*1e3:.1f} "
            f"| {r['t_collective_s']*1e3:.1f} | {r['bottleneck']} "
            f"| {r['useful_flops_fraction']:.3f} "
            f"| {r['roofline_fraction']:.3f} |")
    return out


if __name__ == "__main__":
    print("## Dry-run")
    for mesh in ("16x16", "2x16x16"):
        print("\n".join(dryrun_table(mesh)))
        print()
    print("## Roofline (single-pod)")
    print("\n".join(roofline_table("16x16")))
