"""Bench-regression gate: diff a freshly produced ``BENCH_<tag>.json``
against the committed baseline in ``benchmarks/baselines/``.

    PYTHONPATH=src python -m benchmarks.bench_diff BENCH_ci-jax-fusion1.json
    PYTHONPATH=src python -m benchmarks.bench_diff --update BENCH_*.json

The baseline file is looked up by the payload's OWN tag (``baselines/
BENCH_<tag>.json``), so a CI leg can only ever be compared against the
baseline seeded for that exact matrix cell.

Field classes (see benchmarks/README.md for the schema):

  exact  — transfer/copy COUNTERS: ``copies``, ``bytes_copied``,
           ``h2d_transfers``, ``h2d_bytes``, ``d2h_transfers``,
           ``d2h_bytes``, ``dim_h2d_transfers``, ``dim_h2d_bytes`` and
           ``segment_compiles`` inside every section's ``cache_stats``, the whole
           ``counters`` subtree a section may carry (per-flow fused/unfused
           dispatch + transfer counts), every section's ``status``, and the
           payload's backend/mode/flow_style.  These are deterministic for a
           fixed seed and split count — ANY drift is a real behaviour change
           (a lost fusion, a new per-chunk sync, a changed kernel route) and
           fails the gate.
  band   — wall-clock (``wall_s``, rtol ``BENCH_DIFF_WALL_RTOL``, default
           10.0 — generous because CI machines vary; the gate is the
           counters, not the clock) and the arena pool counters
           (``arena_hits`` / ``arena_misses`` / ``arena_bytes_reused``,
           rtol ``BENCH_DIFF_ARENA_RTOL``, default 0.75 + absolute slack)
           — arena reuse depends on worker thread timing, so exact equality
           would flake (deviation from a strict all-exact diff, documented
           in benchmarks/README.md).

Missing/extra sections are errors: a section silently dropping out of the
bench is exactly the regression a green CI must not hide.

A failing diff prints ONE summary table of every gated field (fresh vs
baseline, field class, ok/REGRESS) before the per-problem lines and the
non-zero exit — all counter deltas are visible from a single red CI log.

``--update`` rewrites the baselines from the fresh files instead of
diffing — run locally after an INTENDED perf-behaviour change and commit
the result.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
from typing import List

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")

#: cache_stats fields compared exactly (deterministic counters); the
#: fault-tolerance trio (retries/degradations/faults_injected) is zero in
#: every committed baseline — CI legs run fault-free, so ANY nonzero value
#: means a kernel route silently degraded or something retried mid-bench
EXACT_STATS = ("copies", "bytes_copied", "h2d_transfers", "h2d_bytes",
               "d2h_transfers", "d2h_bytes", "dim_h2d_transfers",
               "dim_h2d_bytes", "segment_compiles", "retries",
               "degradations", "faults_injected")
#: cache_stats fields compared with a tolerance band (thread-timing noise)
ARENA_STATS = ("arena_hits", "arena_misses", "arena_bytes_reused")
#: top-level payload fields that must match exactly
EXACT_META = ("tag", "mode", "backend", "flow_style")

WALL_RTOL = float(os.environ.get("BENCH_DIFF_WALL_RTOL", "10.0"))
ARENA_RTOL = float(os.environ.get("BENCH_DIFF_ARENA_RTOL", "0.75"))
#: absolute slack for arena counters: tiny baselines (a handful of hits)
#: fluctuate by a few either way regardless of rtol
ARENA_ATOL = 64


def _within(fresh: float, base: float, rtol: float, atol: float = 0.0) -> bool:
    return abs(fresh - base) <= atol + rtol * abs(base)


def _diff_exact_tree(fresh, base, path: str, problems: List[str]) -> None:
    """Recursive exact comparison (the ``counters`` subtree)."""
    if isinstance(base, dict) and isinstance(fresh, dict):
        for k in sorted(set(base) | set(fresh)):
            if k not in fresh:
                problems.append(f"{path}.{k}: missing from fresh run")
            elif k not in base:
                problems.append(f"{path}.{k}: not in baseline "
                                f"(run --update to accept)")
            else:
                _diff_exact_tree(fresh[k], base[k], f"{path}.{k}", problems)
    elif fresh != base:
        problems.append(f"{path}: {fresh!r} != baseline {base!r}")


def diff_payload(fresh: dict, base: dict) -> List[str]:
    """All regressions of ``fresh`` vs ``base`` as human-readable strings
    (empty list == gate passes)."""
    problems: List[str] = []
    for k in EXACT_META:
        if fresh.get(k) != base.get(k):
            problems.append(f"{k}: {fresh.get(k)!r} != baseline "
                            f"{base.get(k)!r}")
    fs, bs = fresh.get("sections", {}), base.get("sections", {})
    for name in sorted(set(bs) - set(fs)):
        problems.append(f"section {name}: missing from fresh run")
    for name in sorted(set(fs) - set(bs)):
        problems.append(f"section {name}: not in baseline "
                        f"(run --update to accept)")
    for name in sorted(set(fs) & set(bs)):
        f_sec, b_sec = fs[name], bs[name]
        if f_sec.get("status") != b_sec.get("status"):
            problems.append(f"{name}.status: {f_sec.get('status')!r} != "
                            f"baseline {b_sec.get('status')!r}")
        f_cs = f_sec.get("cache_stats", {})
        b_cs = b_sec.get("cache_stats", {})
        for field in EXACT_STATS:
            if f_cs.get(field) != b_cs.get(field):
                problems.append(
                    f"{name}.cache_stats.{field}: {f_cs.get(field)} != "
                    f"baseline {b_cs.get(field)} (exact counter)")
        for field in ARENA_STATS:
            fv, bv = f_cs.get(field, 0), b_cs.get(field, 0)
            if not _within(fv, bv, ARENA_RTOL, ARENA_ATOL):
                problems.append(
                    f"{name}.cache_stats.{field}: {fv} outside "
                    f"{ARENA_RTOL:.0%}+{ARENA_ATOL} band of baseline {bv}")
        fw, bw = f_sec.get("wall_s", 0.0), b_sec.get("wall_s", 0.0)
        if not _within(fw, bw, WALL_RTOL):
            problems.append(f"{name}.wall_s: {fw} outside {WALL_RTOL:.0f}x "
                            f"band of baseline {bw}")
        if "counters" in b_sec or "counters" in f_sec:
            _diff_exact_tree(f_sec.get("counters", {}),
                             b_sec.get("counters", {}),
                             f"{name}.counters", problems)
    return problems


def summary_rows(fresh: dict, base: dict) -> List[tuple]:
    """EVERY gated field as ``(path, class, fresh, baseline, status)`` —
    the full-context table printed with a failing diff, so one red CI run
    shows all counter deltas at once instead of only the first problems."""
    rows: List[tuple] = []

    def add(path, klass, fv, bv, ok):
        rows.append((path, klass, fv, bv, "ok" if ok else "REGRESS"))

    for k in EXACT_META:
        add(k, "exact", fresh.get(k), base.get(k),
            fresh.get(k) == base.get(k))
    fs, bs = fresh.get("sections", {}), base.get("sections", {})
    for name in sorted(set(fs) | set(bs)):
        f_sec, b_sec = fs.get(name), bs.get(name)
        if f_sec is None or b_sec is None:
            add(name, "sect", "present" if f_sec else "MISSING",
                "present" if b_sec else "MISSING", False)
            continue
        add(f"{name}.status", "exact", f_sec.get("status"),
            b_sec.get("status"), f_sec.get("status") == b_sec.get("status"))
        f_cs = f_sec.get("cache_stats", {})
        b_cs = b_sec.get("cache_stats", {})
        for field in EXACT_STATS:
            add(f"{name}.{field}", "exact", f_cs.get(field),
                b_cs.get(field), f_cs.get(field) == b_cs.get(field))
        for field in ARENA_STATS:
            fv, bv = f_cs.get(field, 0), b_cs.get(field, 0)
            add(f"{name}.{field}", "band", fv, bv,
                _within(fv, bv, ARENA_RTOL, ARENA_ATOL))
        fw, bw = f_sec.get("wall_s", 0.0), b_sec.get("wall_s", 0.0)
        add(f"{name}.wall_s", "band", fw, bw, _within(fw, bw, WALL_RTOL))

        def walk(fv, bv, path):
            if isinstance(fv, dict) or isinstance(bv, dict):
                fd = fv if isinstance(fv, dict) else {}
                bd = bv if isinstance(bv, dict) else {}
                for k in sorted(set(fd) | set(bd)):
                    walk(fd.get(k), bd.get(k), f"{path}.{k}")
            else:
                add(path, "exact", fv, bv, fv == bv)
        if "counters" in f_sec or "counters" in b_sec:
            walk(f_sec.get("counters", {}), b_sec.get("counters", {}),
                 f"{name}.counters")
    return rows


def render_summary(rows: List[tuple]) -> List[str]:
    w = max(len(r[0]) for r in rows) if rows else 5
    lines = [f"  {'field'.ljust(w)}  {'class':5}  "
             f"{'fresh':>14}  {'baseline':>14}  status"]
    for path, klass, fv, bv, status in rows:
        lines.append(f"  {path.ljust(w)}  {klass:5}  "
                     f"{str(fv):>14}  {str(bv):>14}  {status}")
    return lines


def _baseline_path(tag: str) -> str:
    return os.path.join(BASELINE_DIR, f"BENCH_{tag}.json")


def main(argv: List[str] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    update = "--update" in args
    paths = [a for a in args if a != "--update"]
    if not paths:
        print("usage: python -m benchmarks.bench_diff [--update] "
              "BENCH_<tag>.json [...]")
        return 2
    rc = 0
    for path in paths:
        with open(path) as f:
            fresh = json.load(f)
        tag = fresh.get("tag", "local")
        bpath = _baseline_path(tag)
        if update:
            os.makedirs(BASELINE_DIR, exist_ok=True)
            shutil.copyfile(path, bpath)
            print(f"bench_diff: baseline {bpath} updated from {path}")
            continue
        if not os.path.exists(bpath):
            print(f"bench_diff: no baseline for tag {tag!r} ({bpath}); "
                  f"seed it with --update")
            rc = 1
            continue
        with open(bpath) as f:
            base = json.load(f)
        problems = diff_payload(fresh, base)
        if problems:
            print(f"bench_diff: {path} vs {bpath}: "
                  f"{len(problems)} regression(s)")
            # the full comparison table FIRST — every gated field with its
            # fresh/baseline values — then the individual regression lines
            for line in render_summary(summary_rows(fresh, base)):
                print(line)
            for p in problems:
                print(f"  REGRESSION {p}")
            rc = 1
        else:
            n = len(fresh.get("sections", {}))
            print(f"bench_diff: {path} vs {bpath}: OK ({n} sections, "
                  f"counters exact, wall/arena in band)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
