"""Tracing-overhead gate: assert the ``REPRO_TRACE=1`` instrumented path
stays within ``TRACE_OVERHEAD_RTOL`` (default 10%) of the untraced wall on
Q4.1, and that a traced run's metric counters reconcile EXACTLY with its
``EngineRun`` cache statistics.

    PYTHONPATH=src python -m benchmarks.trace_overhead

Interleaves best-of-N wall measurements (off, on, off, on, ...) so machine
drift hits both sides equally, writes the trace artifact to
``TRACE_<tag>.json`` (uploaded by the CI smoke legs, loadable in
ui.perfetto.dev) and exits non-zero on an overhead or reconciliation
failure.  Scale via env: TRACE_ROWS (default 200,000), TRACE_REPEATS
(default 5), TRACE_OVERHEAD_RTOL (default 0.10).
"""
from __future__ import annotations

import json
import os
import sys

ROWS = int(os.environ.get("TRACE_ROWS", "200000"))
REPEATS = int(os.environ.get("TRACE_REPEATS", "5"))
RTOL = float(os.environ.get("TRACE_OVERHEAD_RTOL", "0.10"))

#: metric counter -> EngineRun field pairs that must agree exactly
RECONCILE = ("copies", "bytes_copied", "h2d_transfers", "h2d_bytes",
             "d2h_transfers", "d2h_bytes", "dispatch_calls",
             "arena_hits", "arena_misses", "arena_bytes_reused")


def _run_once(data, traced: bool):
    from repro.core import OptimizeOptions, StreamingEngine, config
    from repro.etl import BUILDERS
    if traced:
        os.environ[config.ENV_TRACE] = "1"
    else:
        os.environ.pop(config.ENV_TRACE, None)
    qf = BUILDERS["Q4.1"](data)
    return StreamingEngine(qf.flow, OptimizeOptions(num_splits=4)).run()


def main() -> int:
    from repro.core import config
    from repro.etl.ssb import generate

    tag = os.environ.get("BENCH_TAG", "").strip() or "local"
    trace_path = os.environ.get(config.ENV_TRACE_PATH) or f"TRACE_{tag}.json"
    os.environ[config.ENV_TRACE_PATH] = trace_path
    prior_trace = os.environ.get(config.ENV_TRACE)

    data = generate(lineorder_rows=ROWS, customers=2_000, suppliers=200,
                    parts=1_000, seed=5)
    _run_once(data, traced=False)           # warm caches/JIT off the clock

    walls = {False: [], True: []}
    last_traced = None
    try:
        for _ in range(REPEATS):
            for traced in (False, True):    # interleaved: drift hits both
                r = _run_once(data, traced)
                walls[traced].append(r.wall_time)
                if traced:
                    last_traced = r
    finally:
        if prior_trace is None:
            os.environ.pop(config.ENV_TRACE, None)
        else:
            os.environ[config.ENV_TRACE] = prior_trace

    off, on = min(walls[False]), min(walls[True])
    ratio = on / off if off else float("inf")
    print(f"trace_overhead,rows={ROWS},off_s={off:.4f},on_s={on:.4f},"
          f"ratio={ratio:.3f},limit={1 + RTOL:.2f}")

    failures = 0
    if ratio > 1.0 + RTOL:
        print(f"trace_overhead,FAIL,traced wall {on:.4f}s exceeds "
              f"{1 + RTOL:.2f}x untraced {off:.4f}s")
        failures += 1

    # exact reconciliation: tracer counters == the same run's CacheStats
    counters = last_traced.metrics.get("counters", {})
    for field in RECONCILE:
        got, want = counters.get(field, 0), getattr(last_traced, field)
        ok = got == want
        print(f"trace_reconcile,{field},{got},{want},"
              f"{'ok' if ok else 'FAIL'}")
        failures += 0 if ok else 1

    if last_traced.trace_file:
        with open(last_traced.trace_file) as f:
            payload = json.load(f)
        n_events = len(payload.get("traceEvents", []))
        print(f"trace_artifact,{last_traced.trace_file},events={n_events}")
        if not n_events:
            print("trace_artifact,FAIL,empty traceEvents")
            failures += 1
    else:
        print("trace_artifact,FAIL,no trace file exported")
        failures += 1

    print(f"trace_overhead,{'FAIL' if failures else 'PASS'},"
          f"{failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
