"""Figure 15 — the framework's own gains on Q4.1 (fact size scaled):
  sequential WITHOUT shared caching   (the ordinary baseline)
  sequential WITH shared caching      (paper: ~10% faster — REAL wall-clock:
                                       copy removal needs no extra cores)
  pipelined m=8                       (real 1-core + simulated 8-core)

Emits CSV: scale,variant,wall_s,copies,bytes_copied_mb
"""
from __future__ import annotations

from repro.core.simulate import speedup_curve

from .common import (BENCH_REPEATS, BENCH_ROWS,
                     activity_costs_from_sequential, run_optimized,
                     run_ordinary, ssb_data)


def run(rows_scales=(0.5, 1.0, 2.0)) -> list:
    out = ["fig15.scale,variant,wall_s,copies,bytes_copied_mb"]
    for scale in rows_scales:
        rows = int(BENCH_ROWS * scale)
        data = ssb_data(rows)

        best_ord = None
        best_shared = None
        best_pipe = None
        for _ in range(BENCH_REPEATS):
            r, _ = run_ordinary("Q4.1", data)
            best_ord = r if best_ord is None or \
                r.wall_time < best_ord.wall_time else best_ord
            r, _ = run_optimized("Q4.1", data, num_splits=8,
                                 pipelined=False, concurrent_trees=False)
            best_shared = r if best_shared is None or \
                r.wall_time < best_shared.wall_time else best_shared
            r, _ = run_optimized("Q4.1", data, num_splits=8)
            best_pipe = r if best_pipe is None or \
                r.wall_time < best_pipe.wall_time else best_pipe

        for name, r in (("ordinary_seq", best_ord),
                        ("shared_cache_seq", best_shared),
                        ("pipelined_m8_real1core", best_pipe)):
            out.append(f"fig15.{scale},{name},{r.wall_time:.3f},"
                       f"{r.copies},{r.bytes_copied/1e6:.1f}")
        gain = (best_ord.wall_time - best_shared.wall_time) \
            / best_ord.wall_time * 100
        out.append(f"fig15.{scale},shared_cache_gain_pct,{gain:.1f},,"
                   f"paper=~10")

        # simulated 8-core pipelined speedup vs the sequential run
        costs, _ = activity_costs_from_sequential("Q4.1", data)
        sim = speedup_curve(list(costs.values()), rows, [8], cores=8,
                            t0=0.002, switch_cost=0.004)[8]
        out.append(f"fig15.{scale},pipelined_m8_sim8core_speedup,"
                   f"{sim:.2f},,paper=4.7x_vs_ordinary")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
