"""Segment fusion + CacheArena vs the PR-3 adaptive path, both backends.

For each flow x backend the section runs the streaming engine twice at
``optimize_level=2`` — fusion OFF (the PR-3 adaptive baseline) and fusion ON
(``fuse_segments=True``: maximal row-synchronized chains collapsed into
single compiled kernels, per-chunk buffers recycled through the arena) —
verifies the fused run's sink output is byte-identical to the baseline, and
reports wall time, backend dispatch calls, h2d/d2h transfer counts and the
arena hit/miss/bytes-reused counters.

Emits CSV:
  fusion.flow,backend,mode,wall_s,dispatch_calls,h2d_n,d2h_n,arena_hits,arena_misses,arena_MB_reused
  fusion.flow.speedup,backend,fused_vs_unfused,<x>

The ``--smoke fusion`` part additionally ENFORCES the reduction: fused
dispatch calls must drop versus unfused, and on the jax backend the h2d
transfer count must drop (and d2h not grow) — the acceptance gate for the
fused-kernel path.
"""
from __future__ import annotations

import numpy as np

from repro.core import OptimizeOptions, StreamingEngine, available_backends
from repro.etl import BUILDERS

from .common import BENCH_REPEATS, BENCH_ROWS, ssb_data

FLOWS = ("Q4.1", "Q4.1s")
BACKENDS = ("numpy", "jax")
NUM_SPLITS = 8
CALIBRATION_ROWS = 65_536


def _run(qname: str, data, backend, fused: bool, num_splits: int = NUM_SPLITS,
         calibration_rows: int = CALIBRATION_ROWS):
    qf = BUILDERS[qname](data)
    run = StreamingEngine(qf.flow, OptimizeOptions(
        num_splits=num_splits, backend=backend, optimize_level=2,
        calibration_rows=calibration_rows, fuse_segments=fused)).run()
    return run, qf.sink.result()


def _assert_identical(fused, baseline, label: str) -> None:
    assert set(fused) == set(baseline), f"{label}: column sets differ"
    for k in baseline:
        assert fused[k].dtype == baseline[k].dtype, f"{label}: dtype of {k}"
        np.testing.assert_array_equal(fused[k], baseline[k],
                                      err_msg=f"{label} column {k}")


def _csv(prefix: str, backend, mode: str, r) -> str:
    return (f"{prefix},{backend},{mode},{r.wall_time:.4f},"
            f"{r.dispatch_calls},{r.h2d_transfers},{r.d2h_transfers},"
            f"{r.arena_hits},{r.arena_misses},"
            f"{r.arena_bytes_reused/1e6:.1f}")


def run(rows: int = None) -> list:
    rows = rows or max(200_000, BENCH_ROWS // 4)
    data = ssb_data(rows)
    out = ["fusion.flow,backend,mode,wall_s,dispatch_calls,h2d_n,d2h_n,"
           "arena_hits,arena_misses,arena_MB_reused"]
    backends = [b for b in BACKENDS if b in available_backends()]
    for flow in FLOWS:
        for backend in backends:
            best = {}
            results = {}
            for fused, mode in ((False, "unfused"), (True, "fused")):
                for _ in range(max(1, BENCH_REPEATS)):
                    r, res = _run(flow, data, backend, fused)
                    if mode not in best or r.wall_time < best[mode].wall_time:
                        best[mode] = r
                        results[mode] = res
                out.append(_csv(f"fusion.{flow}", backend, mode, best[mode]))
            _assert_identical(results["fused"], results["unfused"],
                              f"{flow}/{backend}")
            speedup = (best["unfused"].wall_time
                       / max(best["fused"].wall_time, 1e-9))
            out.append(f"fusion.{flow}.speedup,{backend},fused_vs_unfused,"
                       f"{speedup:.3f}")
    return out


def smoke(data):
    """CI part: fused-vs-unfused byte equality on Q4.1/Q4.1s under the
    active backend, with the reductions ENFORCED — fewer backend dispatch
    calls always; on a mask-deferring backend (jax) fewer h2d transfers,
    STRICTLY fewer d2h transfers, and the per-chunk keep-mask syncs gone:
    deferral replaces one mask compact per chunk with one at the terminal
    Aggregate's finish, so unfused_d2h - fused_d2h >= num_splits - 1.

    Returns ``(failures, extras)`` where extras carries the per-flow
    transfer counters for the bench JSON (``bench_diff`` locks them in
    against the committed baselines)."""
    import traceback

    from repro.core import get_default_backend
    backend = get_default_backend()
    failures = 0
    counters = {}
    num_splits = 4
    for flow in FLOWS:
        try:
            r_u, unfused = _run(flow, data, backend=None, fused=False,
                                num_splits=num_splits,
                                calibration_rows=8_192)
            r_f, fused = _run(flow, data, backend=None, fused=True,
                              num_splits=num_splits, calibration_rows=8_192)
            _assert_identical(fused, unfused, flow)
            assert any(x["rule"] == "fuse-segment" for x in r_f.rewrites), \
                f"{flow}: no fuse-segment rewrite applied"
            assert any(x["rule"] == "fuse-segment-aggregate"
                       for x in r_f.rewrites), \
                f"{flow}: no fuse-segment-aggregate (mask deferral) rewrite"
            assert r_f.dispatch_calls < r_u.dispatch_calls, \
                (f"{flow}: fused dispatch calls {r_f.dispatch_calls} !< "
                 f"unfused {r_u.dispatch_calls}")
            if backend.supports_segment_defer:
                assert r_f.h2d_transfers < r_u.h2d_transfers, \
                    (f"{flow}: fused h2d transfers {r_f.h2d_transfers} !< "
                     f"unfused {r_u.h2d_transfers}")
                assert r_f.d2h_transfers < r_u.d2h_transfers, \
                    (f"{flow}: fused d2h transfers {r_f.d2h_transfers} !< "
                     f"unfused {r_u.d2h_transfers}")
                # zero per-chunk keep-mask syncs: the unfused run pays one
                # mask compact per chunk, the fused run exactly one (at the
                # Aggregate's finish)
                saved = r_u.d2h_transfers - r_f.d2h_transfers
                assert saved >= num_splits - 1, \
                    (f"{flow}: only {saved} d2h syncs eliminated; expected "
                     f">= {num_splits - 1} (per-chunk keep-mask compacts)")
            counters[flow] = {
                "unfused": {"dispatch_calls": r_u.dispatch_calls,
                            "h2d_transfers": r_u.h2d_transfers,
                            "d2h_transfers": r_u.d2h_transfers},
                "fused": {"dispatch_calls": r_f.dispatch_calls,
                          "h2d_transfers": r_f.h2d_transfers,
                          "d2h_transfers": r_f.d2h_transfers},
            }
        except Exception:
            traceback.print_exc()
            failures += 1
            print(f"smoke.fusion.{flow},FAIL")
            continue
        print(f"smoke.fusion.{flow},rows_ok,"
              f"dispatch={r_u.dispatch_calls}->{r_f.dispatch_calls},"
              f"h2d_n={r_u.h2d_transfers}->{r_f.h2d_transfers},"
              f"d2h_n={r_u.d2h_transfers}->{r_f.d2h_transfers},"
              f"arena_hits={r_f.arena_hits}")
    return failures, {"counters": counters}


if __name__ == "__main__":
    print("\n".join(run()))
