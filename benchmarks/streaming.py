"""Streaming inter-tree executor vs the accumulate-then-start planner on the
multi-tree SSB dataflows.

Q4.1  — the paper's Figure-11 flow: 3 trees, both boundaries blocked
        (groupby, sort), so streaming can only match the planner.
Q4.1s — Q4.1 with an explicit StageBoundary between the lookup stage and the
        filter/project/expression stage: the T1->T2 boundary is
        ROW-SYNCHRONIZED, so the streaming executor overlaps the two trees
        through a bounded split channel while the planner waits for T1 to
        finish before starting T2.

Emits CSV: flow,engine,wall_s,copies,pool_width,streamed_edges
and a speedup line per flow (optimized wall / streaming wall).
"""
from __future__ import annotations

from .common import BENCH_ROWS, run_optimized, run_streaming, ssb_data

FLOWS = ("Q4.1", "Q4.1s")
NUM_SPLITS = 8


def run(rows: int = None) -> list:
    rows = rows or max(200_000, BENCH_ROWS // 4)
    data = ssb_data(rows)
    out = ["streaming.flow,engine,wall_s,copies,pool_width,streamed_edges"]
    for flow in FLOWS:
        results = {}
        for engine, runner in (("optimized", run_optimized),
                               ("streaming", run_streaming)):
            best = None
            for _ in range(3):
                r, _qf = runner(flow, data, num_splits=NUM_SPLITS)
                if best is None or r.wall_time < best.wall_time:
                    best = r
            results[engine] = best
            out.append(
                f"streaming.{flow},{engine},{best.wall_time:.4f},"
                f"{best.copies},{best.runtime_plan.pool_width},"
                f"{len(best.streamed_edges)}")
        speedup = (results["optimized"].wall_time
                   / max(results["streaming"].wall_time, 1e-9))
        out.append(f"streaming.{flow}.speedup,stream_vs_planner,"
                   f"{speedup:.3f},,,")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
