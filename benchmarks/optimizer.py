"""Cost-based adaptive optimizer (optimize_level=2) vs the static planner on
the multi-tree SSB dataflows, under BOTH operator backends.

For each flow x backend the section runs the streaming engine twice —
``optimize_level=1`` (the paper's static partition/plan) and
``optimize_level=2`` (calibration prefix, statistics-driven rewriting,
measured re-partition/re-plan) — verifies the rewritten run's sink output is
byte-identical to the static run, and reports walls, copies and the applied
rewrites plus the before/after tree counts from the metadata store.

Emits CSV:
  optimizer.flow,backend,mode,wall_s,copies,trees,rewrites
  optimizer.flow.speedup,backend,adaptive_vs_static,<x>
"""
from __future__ import annotations

import numpy as np

from repro.core import (MetadataStore, OptimizeOptions, StreamingEngine,
                        available_backends)
from repro.etl import BUILDERS

from .common import BENCH_REPEATS, BENCH_ROWS, ssb_data

FLOWS = ("Q4.1", "Q4.1s")
BACKENDS = ("numpy", "jax")
NUM_SPLITS = 8
CALIBRATION_ROWS = 65_536


def _run(qname: str, data, backend: str, level: int):
    qf = BUILDERS[qname](data)
    md = MetadataStore()
    run = StreamingEngine(qf.flow, OptimizeOptions(
        num_splits=NUM_SPLITS, backend=backend, optimize_level=level,
        calibration_rows=CALIBRATION_ROWS), metadata=md).run()
    return run, qf.sink.result(), md


def run(rows: int = None) -> list:
    rows = rows or max(200_000, BENCH_ROWS // 4)
    data = ssb_data(rows)
    out = ["optimizer.flow,backend,mode,wall_s,copies,trees,rewrites"]
    backends = [b for b in BACKENDS if b in available_backends()]
    for flow in FLOWS:
        for backend in backends:
            best = {}
            results = {}
            for level, mode in ((1, "static"), (2, "adaptive")):
                for _ in range(max(1, BENCH_REPEATS)):
                    r, res, md = _run(flow, data, backend, level)
                    if mode not in best or r.wall_time < best[mode].wall_time:
                        best[mode] = r
                        results[mode] = (res, md)
                r = best[mode]
                rewrites = ";".join(x["rule"] for x in r.rewrites) or "-"
                out.append(f"optimizer.{flow},{backend},{mode},"
                           f"{r.wall_time:.4f},{r.copies},{len(r.trees)},"
                           f"{rewrites}")
            # the rewritten flow must agree with the static flow exactly
            static, _ = results["static"]
            adaptive, _ = results["adaptive"]
            assert set(static) == set(adaptive), "column sets differ"
            for k in static:
                np.testing.assert_array_equal(
                    adaptive[k], static[k],
                    err_msg=f"{flow}/{backend} adaptive-vs-static column {k}")
            speedup = (best["static"].wall_time
                       / max(best["adaptive"].wall_time, 1e-9))
            out.append(f"optimizer.{flow}.speedup,{backend},"
                       f"adaptive_vs_static,{speedup:.3f}")
    return out


def smoke(data) -> int:
    """CI part: static-vs-adaptive byte equality on Q4.1/Q4.1s (current
    default backend) — the rewrite-safety guard on the real SSB flows."""
    import traceback
    failures = 0
    for flow in FLOWS:
        try:
            r_s, static, _ = _run(flow, data, backend=None, level=1)
            r_a, adaptive, md = _run(flow, data, backend=None, level=2)
            assert set(static) == set(adaptive), "column sets differ"
            for k in static:
                np.testing.assert_array_equal(
                    adaptive[k], static[k],
                    err_msg=f"{flow} adaptive column {k}")
            rec = md.adaptive[next(iter(md.adaptive))]
            assert rec["before"]["plan"]["pool_width"] >= 1
            assert rec["after"]["plan"]["pool_width"] >= 1
        except Exception:
            traceback.print_exc()
            failures += 1
            print(f"smoke.optimizer.{flow},FAIL")
            continue
        rules = ";".join(x["rule"] for x in r_a.rewrites) or "-"
        print(f"smoke.optimizer.{flow},rows_ok,trees={len(r_s.trees)}"
              f"->{len(r_a.trees)},rewrites={rules}")
    return failures


if __name__ == "__main__":
    print("\n".join(run()))
